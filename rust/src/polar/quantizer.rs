//! The end-to-end PolarQuant codec (paper Algorithm 1 + §4.1 layout).
//!
//! Encode: precondition (rotation R) → recursive polar transform →
//! per-level angle quantization → bit-pack. Store the residual radii in
//! fp16 (b_FPN = 16).
//!
//! Decode: unpack codes → centroid angles → inverse polar transform →
//! apply Rᵀ.
//!
//! Hot-path trick (same one the paper's CUDA kernels exploit): for scores
//! q·K̂ᵀ the rotation need not be undone per cached vector — rotate the
//! *query* once (q′ = R·q) and dot against the un-rotated reconstruction,
//! since ⟨Rᵀy, q⟩ = ⟨y, Rq⟩. [`PolarQuantizer::decode_preconditioned`]
//! exposes that path; `model::attention` builds on it.

use crate::math::rotation::{PreconditionKind, Rotation};
use crate::polar::codebook::CodebookSet;
use crate::polar::pack::{BitReader, BitWriter};
use crate::polar::transform::polar_forward;
use crate::quant::fp16::{f16_bits_to_f32, f32_to_f16_bits};
use crate::util::rng::Pcg64;

/// Codec configuration (paper defaults: L=4, bits (4,2,2,2), rotation).
#[derive(Clone, Debug)]
pub struct PolarConfig {
    /// Vector dimension (head_dim); must be divisible by 2^levels.
    pub dim: usize,
    /// Recursion depth L (paper §4.1: 4).
    pub levels: usize,
    /// Bits per angle at each level, len == levels (paper: [4,2,2,2] —
    /// level 1 spans [0,2π), four times the width of the others).
    pub level_bits: Vec<u8>,
    /// Random preconditioner (paper -R variants: Haar rotation).
    pub precondition: PreconditionKind,
    /// Seed for the shared preconditioner (shared across K, V, layers,
    /// heads — paper §4.1).
    pub seed: u64,
}

impl PolarConfig {
    /// Paper §4.1 defaults for dimension `dim`.
    pub fn paper_default(dim: usize) -> Self {
        Self {
            dim,
            levels: 4,
            level_bits: vec![4, 2, 2, 2],
            precondition: PreconditionKind::Haar,
            seed: 0x504f4c4152, // "POLAR"
        }
    }

    /// Same layout without preconditioning (paper's "PolarQuant" row).
    pub fn paper_default_no_precondition(dim: usize) -> Self {
        Self { precondition: PreconditionKind::None, ..Self::paper_default(dim) }
    }

    pub fn validate(&self) {
        assert!(self.levels >= 1 && self.levels <= 16);
        assert_eq!(self.level_bits.len(), self.levels, "bits per level");
        assert!(
            self.dim % (1 << self.levels) == 0,
            "dim {} not divisible by 2^{}",
            self.dim,
            self.levels
        );
        for &b in &self.level_bits {
            assert!(b >= 1 && b <= 12, "angle bits in 1..=12");
        }
    }

    /// Residual radii per vector.
    pub fn num_radii(&self) -> usize {
        self.dim >> self.levels
    }

    /// Packed angle bits per vector.
    pub fn angle_bits(&self) -> usize {
        (0..self.levels)
            .map(|l| (self.dim >> (l + 1)) * self.level_bits[l] as usize)
            .sum()
    }

    /// Total storage bits per vector (radii fp16 + packed angles, angles
    /// rounded up to whole bytes as allocated).
    pub fn bits_per_vector(&self) -> usize {
        self.num_radii() * 16 + self.angle_bits().div_ceil(8) * 8
    }

    /// Effective bits per coordinate (paper: 3.875 at d=128, L=4, (4,2,2,2)).
    pub fn bits_per_coordinate(&self) -> f64 {
        self.bits_per_vector() as f64 / self.dim as f64
    }

    /// Compression ratio versus fp16 storage.
    pub fn compression_vs_fp16(&self) -> f64 {
        16.0 / self.bits_per_coordinate()
    }
}

/// One encoded vector.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedVector {
    /// fp16 bit patterns of the residual radii.
    pub radii: Vec<u16>,
    /// Bit-packed angle codes, levels concatenated low-to-high.
    pub codes: Vec<u8>,
}

impl QuantizedVector {
    pub fn storage_bytes(&self) -> usize {
        self.radii.len() * 2 + self.codes.len()
    }
}

/// Upper bound on residual radii per vector (dim ≤ 256, levels ≥ 4 in
/// every layout we run; generous for ablations with fewer levels).
const MAX_RADII: usize = 64;

/// The codec: configuration + preconditioner + per-level codebooks.
///
/// Decode-side acceleration (§Perf): the only angles a decoder ever sees
/// are codebook centroids — at most 16 per level — so `trig_luts` holds
/// their precomputed (cos, sin) pairs and the decode path does table
/// lookups + multiplies, no trig. `level_offsets` gives each level's bit
/// offset in the packed stream for direct seeking.
#[derive(Clone, Debug)]
pub struct PolarQuantizer {
    pub cfg: PolarConfig,
    pub rotation: Rotation,
    pub codebooks: CodebookSet,
    trig_luts: Vec<Vec<(f32, f32)>>,
    level_offsets: Vec<usize>,
}

/// A query preprocessed for fused scoring against encoded vectors
/// (rotation applied once; level-1 pair contractions pre-tabulated per
/// centroid — the per-token cost is then lookups + ~d multiplies).
pub struct PreparedQuery {
    /// table[j * k1 + c] = rq[2j]·cos(c₁[c]) + rq[2j+1]·sin(c₁[c]).
    level1_table: Vec<f32>,
    k1: usize,
}

impl PolarQuantizer {
    fn finish(cfg: PolarConfig, rotation: Rotation, codebooks: CodebookSet) -> Self {
        let trig_luts = codebooks
            .books
            .iter()
            .map(|b| {
                b.centroids
                    .iter()
                    .map(|&c| {
                        let (s, co) = c.sin_cos();
                        (co, s)
                    })
                    .collect()
            })
            .collect();
        let mut level_offsets = Vec::with_capacity(cfg.levels);
        let mut off = 0usize;
        for l in 0..cfg.levels {
            level_offsets.push(off);
            off += (cfg.dim >> (l + 1)) * cfg.level_bits[l] as usize;
        }
        Self { cfg, rotation, codebooks, trig_luts, level_offsets }
    }

    /// Offline variant: analytic Lloyd-Max codebooks (shared, precomputed).
    pub fn new_offline(cfg: PolarConfig) -> Self {
        cfg.validate();
        let rotation = Rotation::new(cfg.precondition, cfg.dim, cfg.seed);
        let codebooks = CodebookSet::analytic(&cfg.level_bits);
        Self::finish(cfg, rotation, codebooks)
    }

    /// Online variant: fit k-means codebooks to the angles of the supplied
    /// calibration rows (the prefill KV block, paper §4.1 online).
    pub fn new_online(cfg: PolarConfig, calibration_rows: &[f32]) -> Self {
        cfg.validate();
        let d = cfg.dim;
        assert!(
            !calibration_rows.is_empty() && calibration_rows.len() % d == 0,
            "calibration rows must be non-empty multiples of dim"
        );
        let rotation = Rotation::new(cfg.precondition, d, cfg.seed);
        // Gather per-level angles from the preconditioned calibration data.
        let mut level_angles: Vec<Vec<f32>> = vec![Vec::new(); cfg.levels];
        let mut pre = vec![0.0f32; d];
        for row in calibration_rows.chunks(d) {
            rotation.apply(row, &mut pre);
            let rep = polar_forward(&pre, cfg.levels);
            for (l, a) in rep.angles.iter().enumerate() {
                level_angles[l].extend_from_slice(a);
            }
        }
        let mut rng = Pcg64::new(cfg.seed ^ 0x4f4e4c); // "ONL"
        let codebooks = CodebookSet::online(&level_angles, &cfg.level_bits, &mut rng);
        Self::finish(cfg, rotation, codebooks)
    }

    pub fn dim(&self) -> usize {
        self.cfg.dim
    }

    /// Encode one vector.
    // analyze: allow(hot_path_alloc, "builds one QuantizedVector per streamed token per head (not per cached token); the alloc-free encode path is tracked under ROADMAP vectorized decode kernels")
    pub fn encode(&self, x: &[f32]) -> QuantizedVector {
        assert_eq!(x.len(), self.cfg.dim);
        let mut pre = vec![0.0f32; x.len()];
        self.rotation.apply(x, &mut pre);
        let rep = polar_forward(&pre, self.cfg.levels);

        let radii = rep.radii.iter().map(|&r| f32_to_f16_bits(r)).collect();
        let mut w = BitWriter::with_capacity_bits(self.cfg.angle_bits());
        for (l, angles) in rep.angles.iter().enumerate() {
            let book = &self.codebooks.books[l];
            let bits = self.cfg.level_bits[l];
            for &a in angles {
                w.write(book.quantize(a), bits);
            }
        }
        QuantizedVector { radii, codes: w.into_bytes() }
    }

    /// Bytes one encoded vector occupies in a page slot: fp16 radii (LE)
    /// followed by the packed angle codes.
    pub fn vec_slot_bytes(&self) -> usize {
        self.cfg.num_radii() * 2 + self.cfg.angle_bits().div_ceil(8)
    }

    /// Encode one vector straight into a page slot (`dst` sized
    /// [`vec_slot_bytes`](Self::vec_slot_bytes)): radii as little-endian
    /// f16 bits, then the packed codes. Byte-for-byte the same layout
    /// [`encode`](Self::encode) produces, so slot readers and
    /// [`QuantizedVector`] readers see identical streams.
    pub fn encode_into(&self, x: &[f32], dst: &mut [u8]) {
        let q = self.encode(x);
        let nr = q.radii.len();
        debug_assert_eq!(dst.len(), self.vec_slot_bytes());
        for (j, &r) in q.radii.iter().enumerate() {
            dst[2 * j..2 * j + 2].copy_from_slice(&r.to_le_bytes());
        }
        dst[2 * nr..2 * nr + q.codes.len()].copy_from_slice(&q.codes);
        // Zero any slack byte so shared pages compare deterministically.
        for b in dst[2 * nr + q.codes.len()..].iter_mut() {
            *b = 0;
        }
    }

    /// Split a slot written by [`encode_into`](Self::encode_into) into
    /// its (radii, codes) halves, radii decoded to u16 on the stack.
    #[inline]
    fn split_slot<'s>(&self, slot: &'s [u8], rbuf: &mut [u16; MAX_RADII]) -> (usize, &'s [u8]) {
        let nr = self.cfg.num_radii();
        debug_assert!(nr <= MAX_RADII);
        for (j, r) in rbuf[..nr].iter_mut().enumerate() {
            *r = u16::from_le_bytes([slot[2 * j], slot[2 * j + 1]]);
        }
        (nr, &slot[2 * nr..])
    }

    /// Decode into the *preconditioned* basis (no Rᵀ). Hot path for fused
    /// attention: dot this against R·q.
    pub fn decode_preconditioned(&self, q: &QuantizedVector, out: &mut [f32]) {
        self.decode_pre_with(&q.radii, &q.codes, out);
    }

    /// Slot variant of [`decode_preconditioned`](Self::decode_preconditioned).
    pub fn decode_preconditioned_slot(&self, slot: &[u8], out: &mut [f32]) {
        let mut rbuf = [0u16; MAX_RADII];
        let (nr, codes) = self.split_slot(slot, &mut rbuf);
        self.decode_pre_with(&rbuf[..nr], codes, out);
    }

    /// Shared decode core. Allocation- and trig-free (§Perf): radii land
    /// in `out[0..nr]`, then each level expands in place back-to-front
    /// using the centroid (cos, sin) LUTs — `out[2j] = r·cos`,
    /// `out[2j+1] = r·sin` is safe descending because 2j ≥ j.
    fn decode_pre_with(&self, radii: &[u16], codes: &[u8], out: &mut [f32]) {
        let cfg = &self.cfg;
        debug_assert_eq!(out.len(), cfg.dim);
        let nr = cfg.num_radii();
        for j in 0..nr {
            out[j] = f16_bits_to_f32(radii[j]);
        }
        let mut scratch = [0u16; 256];
        let mut m = nr;
        for l in (0..cfg.levels).rev() {
            // Current values occupy out[0..m]; this level has m codes.
            debug_assert_eq!(m, cfg.dim >> (l + 1));
            debug_assert!(m <= scratch.len());
            let bits = cfg.level_bits[l];
            let lut = &self.trig_luts[l];
            self.read_level_codes(codes, l, bits, m, &mut scratch);
            for j in (0..m).rev() {
                let r = out[j];
                let (co, si) = lut[scratch[j] as usize];
                out[2 * j] = r * co;
                out[2 * j + 1] = r * si;
            }
            m *= 2;
        }
    }

    /// Extract one level's codes: byte-aligned fast path, BitReader
    /// fallback for exotic layouts (§Perf).
    #[inline]
    fn read_level_codes(&self, codes: &[u8], l: usize, bits: u8, count: usize, out: &mut [u16]) {
        if !crate::polar::pack::read_fields_fast(
            codes,
            self.level_offsets[l],
            bits,
            count,
            out,
        ) {
            let mut reader = BitReader::new(codes);
            reader.seek(self.level_offsets[l]);
            for c in out[..count].iter_mut() {
                *c = reader.read(bits);
            }
        }
    }

    /// Fused `acc += w · decode_preconditioned(q)` (§Perf): seeds the
    /// expansion with w-scaled radii and writes the last level directly
    /// into the accumulator — one fewer full-width pass than decode+axpy.
    pub fn decode_scaled_accumulate(&self, q: &QuantizedVector, w: f32, acc: &mut [f32]) {
        self.accumulate_with(&q.radii, &q.codes, w, acc);
    }

    /// Slot variant of [`decode_scaled_accumulate`](Self::decode_scaled_accumulate).
    pub fn accumulate_slot(&self, slot: &[u8], w: f32, acc: &mut [f32]) {
        let mut rbuf = [0u16; MAX_RADII];
        let (nr, codes) = self.split_slot(slot, &mut rbuf);
        self.accumulate_with(&rbuf[..nr], codes, w, acc);
    }

    fn accumulate_with(&self, radii: &[u16], codes: &[u8], w: f32, acc: &mut [f32]) {
        let cfg = &self.cfg;
        debug_assert_eq!(acc.len(), cfg.dim);
        let nr = cfg.num_radii();
        let mut tmp = [0.0f32; 128];
        debug_assert!(cfg.dim / 2 <= tmp.len());
        for j in 0..nr {
            tmp[j] = w * f16_bits_to_f32(radii[j]);
        }
        let mut scratch = [0u16; 256];
        let mut m = nr;
        for l in (1..cfg.levels).rev() {
            let bits = cfg.level_bits[l];
            let lut = &self.trig_luts[l];
            self.read_level_codes(codes, l, bits, m, &mut scratch);
            for j in (0..m).rev() {
                let r = tmp[j];
                let (co, si) = lut[scratch[j] as usize];
                tmp[2 * j] = r * co;
                tmp[2 * j + 1] = r * si;
            }
            m *= 2;
        }
        // Last level expands straight into the accumulator.
        let bits = cfg.level_bits[0];
        let lut = &self.trig_luts[0];
        self.read_level_codes(codes, 0, bits, m, &mut scratch);
        for j in 0..m {
            let (co, si) = lut[scratch[j] as usize];
            let r = tmp[j];
            acc[2 * j] += r * co;
            acc[2 * j + 1] += r * si;
        }
    }

    /// Preprocess a query for [`Self::score`]: rotate once and tabulate
    /// the level-1 pair contractions per centroid (d/2 × k₁ fmas, done
    /// once per attention step instead of once per cached token).
    // analyze: allow(hot_path_alloc, "legacy per-sequence path: allocates once per attention step; the serving pool substrate uses prepare_query_into with retained scratch")
    pub fn prepare_query(&self, q: &[f32]) -> PreparedQuery {
        let mut table = Vec::new();
        let mut rot = Vec::new();
        let k1 = self.prepare_query_into(q, &mut table, &mut rot);
        PreparedQuery { level1_table: table, k1 }
    }

    /// Reusable-buffer variant of [`prepare_query`](Self::prepare_query):
    /// fills `table` (resized to d/2 × k₁) and returns k₁, using `rot` as
    /// scratch for the rotated query. The page-codec scratch uses this to
    /// avoid any fresh allocation per head per step.
    pub fn prepare_query_into(&self, q: &[f32], table: &mut Vec<f32>, rot: &mut Vec<f32>) -> usize {
        let d = self.cfg.dim;
        assert_eq!(q.len(), d);
        rot.clear();
        rot.resize(d, 0.0);
        self.rotation.apply(q, rot);
        let lut1 = &self.trig_luts[0];
        let k1 = lut1.len();
        let pairs = d / 2;
        table.clear();
        table.resize(pairs * k1, 0.0);
        for j in 0..pairs {
            let (a, b) = (rot[2 * j], rot[2 * j + 1]);
            let row = &mut table[j * k1..(j + 1) * k1];
            for (c, &(co, si)) in lut1.iter().enumerate() {
                row[c] = a * co + b * si;
            }
        }
        k1
    }

    /// Fused score ⟨decode_preconditioned(code), R·q⟩ without materializing
    /// the reconstruction: contract the expansion tree against the query
    /// bottom-up (level-1 via the prepared table, deeper levels via the
    /// trig LUTs), finishing with a dot against the fp16 radii.
    pub fn score(
        &self,
        prepared: &PreparedQuery,
        code: &QuantizedVector,
        scratch: &mut Vec<f32>,
    ) -> f32 {
        self.score_with(&prepared.level1_table, prepared.k1, &code.radii, &code.codes, scratch)
    }

    /// Slot variant of [`score`](Self::score): the prepared level-1 table
    /// is passed as raw (table, k₁) so callers can keep it in reusable
    /// scratch instead of a [`PreparedQuery`].
    pub fn score_slot(&self, table: &[f32], k1: usize, slot: &[u8], scratch: &mut Vec<f32>) -> f32 {
        let mut rbuf = [0u16; MAX_RADII];
        let (nr, codes) = self.split_slot(slot, &mut rbuf);
        self.score_with(table, k1, &rbuf[..nr], codes, scratch)
    }

    fn score_with(
        &self,
        table: &[f32],
        k1: usize,
        radii: &[u16],
        codes: &[u8],
        scratch: &mut Vec<f32>,
    ) -> f32 {
        let cfg = &self.cfg;
        let d = cfg.dim;
        let mut m = d / 2;
        scratch.clear();
        scratch.resize(m, 0.0);

        let mut codes_buf = [0u16; 256];
        // Level 1: pure lookups.
        {
            let bits = cfg.level_bits[0];
            self.read_level_codes(codes, 0, bits, m, &mut codes_buf);
            for j in 0..m {
                scratch[j] = table[j * k1 + codes_buf[j] as usize];
            }
        }
        // Levels 2..L: contract pairs with centroid trig.
        for l in 1..cfg.levels {
            m /= 2;
            let bits = cfg.level_bits[l];
            let lut = &self.trig_luts[l];
            self.read_level_codes(codes, l, bits, m, &mut codes_buf);
            for j in 0..m {
                let (co, si) = lut[codes_buf[j] as usize];
                scratch[j] = scratch[2 * j] * co + scratch[2 * j + 1] * si;
            }
        }
        // Final: dot with radii.
        let mut s = 0.0f32;
        for (j, &h) in radii.iter().enumerate() {
            s += f16_bits_to_f32(h) * scratch[j];
        }
        s
    }

    /// Full decode (applies Rᵀ) — Algorithm 1 `DeQuant`.
    pub fn decode(&self, q: &QuantizedVector, out: &mut [f32]) {
        let d = self.cfg.dim;
        assert_eq!(out.len(), d);
        let mut pre = vec![0.0f32; d];
        self.decode_preconditioned(q, &mut pre);
        self.rotation.apply_t(&pre, out);
    }

    /// Full decode (applies Rᵀ) from a page slot written by
    /// [`encode_into`](Self::encode_into).
    pub fn decode_slot(&self, slot: &[u8], out: &mut [f32]) {
        let d = self.cfg.dim;
        assert_eq!(out.len(), d);
        let mut pre = vec![0.0f32; d];
        self.decode_preconditioned_slot(slot, &mut pre);
        self.rotation.apply_t(&pre, out);
    }

    /// Rotate a query into the preconditioned basis (once per attention
    /// call; pairs with [`Self::decode_preconditioned`]).
    pub fn precondition_query(&self, q: &[f32], out: &mut [f32]) {
        self.rotation.apply(q, out);
    }

    /// Encode a row-major batch.
    pub fn encode_batch(&self, rows: &[f32]) -> Vec<QuantizedVector> {
        assert_eq!(rows.len() % self.cfg.dim, 0);
        rows.chunks(self.cfg.dim).map(|r| self.encode(r)).collect()
    }

    /// Mean relative L2 reconstruction error over a batch (diagnostics).
    pub fn reconstruction_error(&self, rows: &[f32]) -> f64 {
        let d = self.cfg.dim;
        let mut out = vec![0.0f32; d];
        let mut total = 0.0;
        let mut n = 0;
        for row in rows.chunks(d) {
            let q = self.encode(row);
            self.decode(&q, &mut out);
            total += crate::util::stats::rel_l2_error(&out, row);
            n += 1;
        }
        total / n.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::{dot, norm2};
    use crate::util::rng::{Pcg64, Rng};

    fn gaussian_rows(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        let mut v = vec![0.0f32; n * d];
        rng.fill_gaussian(&mut v);
        v
    }

    #[test]
    fn paper_bit_accounting_d128() {
        // §4.1: d=128, L=4, bits (4,2,2,2), radii fp16 → 3.875 bits/coord,
        // ×4.129 vs fp16 (paper quotes ×4.008 vs an extra-overhead layout
        // and 62/16 = 3.875 bits per coord for a 16-block).
        let cfg = PolarConfig::paper_default(128);
        assert_eq!(cfg.num_radii(), 8);
        // Per 16-block: 8·4 + 4·2 + 2·2 + 1·2 = 46 angle bits.
        assert_eq!(cfg.angle_bits(), 8 * 46);
        assert!((cfg.bits_per_coordinate() - 3.875).abs() < 1e-9);
        assert!(cfg.compression_vs_fp16() > 4.0);
    }

    #[test]
    fn bit_accounting_d64() {
        let cfg = PolarConfig::paper_default(64);
        assert_eq!(cfg.num_radii(), 4);
        assert_eq!(cfg.angle_bits(), 184);
        assert!((cfg.bits_per_coordinate() - 3.875).abs() < 1e-9);
    }

    #[test]
    fn encode_decode_small_error_on_gaussian() {
        // Theorem-1 regime: Gaussian inputs, default layout. The relative
        // L2 error at ~3.9 bits/coord should be well under 30%.
        for kind in [PreconditionKind::None, PreconditionKind::Haar, PreconditionKind::Hadamard] {
            let mut cfg = PolarConfig::paper_default(64);
            cfg.precondition = kind;
            let pq = PolarQuantizer::new_offline(cfg);
            let rows = gaussian_rows(64, 64, 3);
            let err = pq.reconstruction_error(&rows);
            assert!(err < 0.30, "{:?}: err {err}", kind);
        }
    }

    #[test]
    fn preconditioning_helps_structured_vectors() {
        // Pathological input: energy on one coordinate with heavy outliers —
        // the case Fig. 2 motivates. Rotation should reduce error materially.
        let d = 64;
        let mut rng = Pcg64::new(9);
        let mut rows = vec![0.0f32; 32 * d];
        for r in 0..32 {
            for j in 0..d {
                rows[r * d + j] = 0.05 * rng.gaussian_f32();
            }
            rows[r * d + 3] = 8.0 + rng.gaussian_f32(); // outlier channel
        }
        let pq_none =
            PolarQuantizer::new_offline(PolarConfig::paper_default_no_precondition(d));
        let pq_rot = PolarQuantizer::new_offline(PolarConfig::paper_default(d));
        let e_none = pq_none.reconstruction_error(&rows);
        let e_rot = pq_rot.reconstruction_error(&rows);
        assert!(
            e_rot < e_none,
            "rotation should help structured data: {e_rot} vs {e_none}"
        );
    }

    #[test]
    fn online_beats_or_matches_offline_on_shifted_data() {
        // Data whose angles deviate from the analytic law (no
        // preconditioning, anisotropic scaling) → online codebooks help.
        let d = 32;
        let mut rng = Pcg64::new(10);
        let mut rows = vec![0.0f32; 128 * d];
        for r in 0..128 {
            for j in 0..d {
                let scale = if j % 2 == 0 { 4.0 } else { 0.25 };
                rows[r * d + j] = scale * rng.gaussian_f32();
            }
        }
        let cfg = PolarConfig::paper_default_no_precondition(d);
        let off = PolarQuantizer::new_offline(cfg.clone());
        let on = PolarQuantizer::new_online(cfg, &rows);
        let e_off = off.reconstruction_error(&rows);
        let e_on = on.reconstruction_error(&rows);
        assert!(e_on <= e_off * 1.02, "online {e_on} vs offline {e_off}");
    }

    #[test]
    fn decode_preconditioned_dot_equals_decoded_dot() {
        // ⟨decode(c), q⟩ == ⟨decode_pre(c), R·q⟩ — the fused-attention
        // identity.
        let d = 64;
        let pq = PolarQuantizer::new_offline(PolarConfig::paper_default(d));
        let rows = gaussian_rows(4, d, 11);
        let q = gaussian_rows(1, d, 12);
        let mut rq = vec![0.0f32; d];
        pq.precondition_query(&q, &mut rq);
        let mut full = vec![0.0f32; d];
        let mut pre = vec![0.0f32; d];
        for row in rows.chunks(d) {
            let c = pq.encode(row);
            pq.decode(&c, &mut full);
            pq.decode_preconditioned(&c, &mut pre);
            let a = dot(&full, &q);
            let b = dot(&pre, &rq);
            assert!((a - b).abs() < 1e-2 * norm2(&q) * norm2(&full).max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn norm_preserved_up_to_fp16() {
        // Radii carry the norm; reconstruction norm must match within the
        // fp16 relative error plus angle-induced distortion bound.
        let d = 64;
        let pq = PolarQuantizer::new_offline(PolarConfig::paper_default(d));
        let rows = gaussian_rows(16, d, 13);
        let mut out = vec![0.0f32; d];
        for row in rows.chunks(d) {
            let c = pq.encode(row);
            pq.decode(&c, &mut out);
            let r_in = norm2(row);
            let r_out = norm2(&out);
            assert!((r_in - r_out).abs() / r_in < 0.02, "{r_in} vs {r_out}");
        }
    }

    #[test]
    fn storage_bytes_match_config() {
        let cfg = PolarConfig::paper_default(64);
        let pq = PolarQuantizer::new_offline(cfg.clone());
        let rows = gaussian_rows(1, 64, 14);
        let c = pq.encode(&rows);
        assert_eq!(c.storage_bytes() * 8, cfg.bits_per_vector());
    }

    #[test]
    fn deterministic_across_instances() {
        let cfg = PolarConfig::paper_default(32);
        let a = PolarQuantizer::new_offline(cfg.clone());
        let b = PolarQuantizer::new_offline(cfg);
        let rows = gaussian_rows(3, 32, 15);
        for row in rows.chunks(32) {
            assert_eq!(a.encode(row), b.encode(row));
        }
    }

    #[test]
    fn scaled_accumulate_matches_decode_axpy() {
        for d in [32usize, 64, 128] {
            let pq = PolarQuantizer::new_offline(PolarConfig::paper_default(d));
            let rows = gaussian_rows(6, d, 31);
            let mut acc_fast = vec![0.0f32; d];
            let mut acc_slow = vec![0.0f32; d];
            let mut buf = vec![0.0f32; d];
            for (i, row) in rows.chunks(d).enumerate() {
                let w = 0.1 + 0.2 * i as f32;
                let c = pq.encode(row);
                pq.decode_scaled_accumulate(&c, w, &mut acc_fast);
                pq.decode_preconditioned(&c, &mut buf);
                for j in 0..d {
                    acc_slow[j] += w * buf[j];
                }
            }
            for (a, b) in acc_fast.iter().zip(&acc_slow) {
                assert!((a - b).abs() < 1e-4 * b.abs().max(1.0), "d={d}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn fused_score_matches_materialized_decode() {
        // score(prepare(q), c) ≡ ⟨decode_preconditioned(c), R·q⟩ — the
        // §Perf fast path must be bit-for-bit faithful to the slow one.
        for d in [32usize, 64, 128] {
            let pq = PolarQuantizer::new_offline(PolarConfig::paper_default(d));
            let rows = gaussian_rows(8, d, 21);
            let q = gaussian_rows(1, d, 22);
            let prepared = pq.prepare_query(&q);
            let mut rq = vec![0.0f32; d];
            pq.precondition_query(&q, &mut rq);
            let mut scratch = Vec::new();
            let mut dec = vec![0.0f32; d];
            for row in rows.chunks(d) {
                let c = pq.encode(row);
                let fast = pq.score(&prepared, &c, &mut scratch);
                pq.decode_preconditioned(&c, &mut dec);
                let slow = dot(&dec, &rq);
                assert!(
                    (fast - slow).abs() < 1e-3 * slow.abs().max(1.0),
                    "d={d}: fused {fast} vs materialized {slow}"
                );
            }
        }
    }

    #[test]
    fn slot_paths_bitwise_match_vector_paths() {
        // The page-slot readers must be numerically indistinguishable
        // from the QuantizedVector readers — the pool substrate's
        // parity with the legacy heap cache rests on this.
        for d in [32usize, 64, 128] {
            let pq = PolarQuantizer::new_offline(PolarConfig::paper_default(d));
            let rows = gaussian_rows(6, d, 41);
            let q = gaussian_rows(1, d, 42);
            let prepared = pq.prepare_query(&q);
            let mut table = Vec::new();
            let mut rot = Vec::new();
            let k1 = pq.prepare_query_into(&q, &mut table, &mut rot);
            assert_eq!(k1, prepared.k1);
            assert_eq!(table, prepared.level1_table);
            let mut slot = vec![0u8; pq.vec_slot_bytes()];
            let mut s1 = Vec::new();
            let mut s2 = Vec::new();
            let mut acc_a = vec![0.0f32; d];
            let mut acc_b = vec![0.0f32; d];
            let mut dec_a = vec![0.0f32; d];
            let mut dec_b = vec![0.0f32; d];
            for (i, row) in rows.chunks(d).enumerate() {
                let c = pq.encode(row);
                pq.encode_into(row, &mut slot);
                assert_eq!(slot.len(), c.storage_bytes());
                let via_vec = pq.score(&prepared, &c, &mut s1);
                let via_slot = pq.score_slot(&table, k1, &slot, &mut s2);
                assert_eq!(via_vec.to_bits(), via_slot.to_bits(), "d={d}");
                let w = 0.3 + 0.1 * i as f32;
                pq.decode_scaled_accumulate(&c, w, &mut acc_a);
                pq.accumulate_slot(&slot, w, &mut acc_b);
                pq.decode(&c, &mut dec_a);
                pq.decode_slot(&slot, &mut dec_b);
                assert_eq!(dec_a, dec_b, "d={d}");
            }
            for (a, b) in acc_a.iter().zip(&acc_b) {
                assert_eq!(a.to_bits(), b.to_bits(), "d={d}");
            }
        }
    }

    #[test]
    fn zero_vector_roundtrip() {
        let pq = PolarQuantizer::new_offline(PolarConfig::paper_default(32));
        let x = vec![0.0f32; 32];
        let c = pq.encode(&x);
        let mut out = vec![1.0f32; 32];
        pq.decode(&c, &mut out);
        assert!(norm2(&out) < 1e-5, "zero maps to ~zero");
    }

    #[test]
    fn varying_level_bits_accounting() {
        // Ablation layouts must account correctly.
        let cfg = PolarConfig {
            dim: 64,
            levels: 3,
            level_bits: vec![5, 3, 2],
            precondition: PreconditionKind::None,
            seed: 1,
        };
        cfg.validate();
        // level1: 32·5=160, level2: 16·3=48, level3: 8·2=16 → 224 bits,
        // radii: 8·16=128 → 352 bits → 5.5 b/coord.
        assert_eq!(cfg.angle_bits(), 224);
        assert!((cfg.bits_per_coordinate() - 5.5).abs() < 1e-9);
    }
}
