//! Extension (paper §6): PolarQuant for vector similarity search.
//!
//! The conclusion notes the codec "extends beyond KV cache compression,
//! offering potential applications in … general vector similarity search".
//! This module is that application: a maximum-inner-product / cosine
//! search index whose database vectors are stored as polar codes
//! (3.875 bits/coordinate) and scored with the fused query-side tree
//! contraction from the serving hot path — the same memory/accuracy trade
//! as the KV cache, now for retrieval.
//!
//! Search is exhaustive-scan over codes (no graph/IVF structure — the
//! contribution under test is the *encoding*, and scan isolates it) with
//! an optional exact re-ranking of the top candidates, the standard
//! compressed-index recipe (à la PQ + re-rank).

use crate::polar::quantizer::{PolarConfig, PolarQuantizer, QuantizedVector};

/// A compressed similarity index.
pub struct PolarIndex {
    pub quantizer: PolarQuantizer,
    codes: Vec<QuantizedVector>,
    /// Optional fp32 originals kept for re-ranking (costs memory; off by
    /// default — callers wanting re-rank keep their own store).
    rerank_store: Option<Vec<f32>>,
    d: usize,
}

/// A scored search hit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Hit {
    pub index: usize,
    pub score: f32,
}

impl PolarIndex {
    /// Build from row-major vectors (n × d). `keep_originals` enables
    /// exact re-ranking at ~17% extra memory per 16 candidates re-ranked.
    pub fn build(vectors: &[f32], d: usize, keep_originals: bool) -> Self {
        let cfg = PolarConfig::paper_default(d);
        let quantizer = PolarQuantizer::new_offline(cfg);
        let codes = quantizer.encode_batch(vectors);
        Self {
            quantizer,
            codes,
            rerank_store: keep_originals.then(|| vectors.to_vec()),
            d,
        }
    }

    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Bytes used by the compressed codes.
    pub fn memory_bytes(&self) -> usize {
        self.codes.iter().map(|c| c.storage_bytes()).sum()
    }

    /// Top-k by approximate inner product (fused scoring over codes).
    pub fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        assert_eq!(query.len(), self.d);
        let prepared = self.quantizer.prepare_query(query);
        let mut scratch = Vec::with_capacity(self.d / 2);
        let mut hits: Vec<Hit> = self
            .codes
            .iter()
            .enumerate()
            .map(|(i, c)| Hit { index: i, score: self.quantizer.score(&prepared, c, &mut scratch) })
            .collect();
        hits.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        hits.truncate(k);
        hits
    }

    /// Top-k with exact re-ranking of the top `k × expand` candidates
    /// (requires `keep_originals`).
    pub fn search_rerank(&self, query: &[f32], k: usize, expand: usize) -> Vec<Hit> {
        let store = self
            .rerank_store
            .as_ref()
            .expect("index built without originals; use search()");
        let mut cand = self.search(query, k * expand.max(1));
        for h in cand.iter_mut() {
            let row = &store[h.index * self.d..(h.index + 1) * self.d];
            h.score = crate::math::linalg::dot(row, query);
        }
        cand.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
        cand.truncate(k);
        cand
    }
}

/// Recall@k of approximate hits against an exact top-k ground truth.
pub fn recall_at_k(approx: &[Hit], exact: &[Hit]) -> f64 {
    if exact.is_empty() {
        return 1.0;
    }
    let truth: std::collections::BTreeSet<usize> = exact.iter().map(|h| h.index).collect();
    let got = approx.iter().filter(|h| truth.contains(&h.index)).count();
    got as f64 / exact.len() as f64
}

/// Exact top-k by brute force (ground truth for evaluation).
pub fn exact_topk(vectors: &[f32], d: usize, query: &[f32], k: usize) -> Vec<Hit> {
    let mut hits: Vec<Hit> = vectors
        .chunks(d)
        .enumerate()
        .map(|(i, row)| Hit { index: i, score: crate::math::linalg::dot(row, query) })
        .collect();
    hits.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap());
    hits.truncate(k);
    hits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng};

    fn dataset(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed);
        let mut v = vec![0.0f32; n * d];
        rng.fill_gaussian(&mut v);
        v
    }

    #[test]
    fn finds_exact_duplicate_first() {
        let d = 64;
        let vectors = dataset(256, d, 1);
        let idx = PolarIndex::build(&vectors, d, false);
        // Query = vector 100 itself → must be the top hit.
        let q = vectors[100 * d..101 * d].to_vec();
        let hits = idx.search(&q, 5);
        assert_eq!(hits[0].index, 100);
    }

    #[test]
    fn recall_at_10_high_on_gaussian() {
        let d = 64;
        let n = 512;
        let vectors = dataset(n, d, 2);
        let idx = PolarIndex::build(&vectors, d, false);
        let mut rng = Pcg64::new(3);
        let mut total = 0.0;
        let trials = 20;
        for _ in 0..trials {
            let mut q = vec![0.0f32; d];
            rng.fill_gaussian(&mut q);
            let approx = idx.search(&q, 10);
            let exact = exact_topk(&vectors, d, &q, 10);
            total += recall_at_k(&approx, &exact);
        }
        let recall = total / trials as f64;
        assert!(recall > 0.7, "recall@10 {recall}");
    }

    #[test]
    fn rerank_recovers_exact_topk() {
        let d = 64;
        let n = 512;
        let vectors = dataset(n, d, 4);
        let idx = PolarIndex::build(&vectors, d, true);
        let mut rng = Pcg64::new(5);
        let mut q = vec![0.0f32; d];
        rng.fill_gaussian(&mut q);
        let exact = exact_topk(&vectors, d, &q, 5);
        let reranked = idx.search_rerank(&q, 5, 8);
        let r = recall_at_k(&reranked, &exact);
        assert!(r >= 0.8, "rerank recall {r}");
        // Re-ranked scores are exact dots.
        for h in &reranked {
            let want = crate::math::linalg::dot(&vectors[h.index * d..(h.index + 1) * d], &q);
            assert!((h.score - want).abs() < 1e-4);
        }
    }

    #[test]
    fn memory_is_quarter_of_fp16() {
        let d = 64;
        let vectors = dataset(128, d, 6);
        let idx = PolarIndex::build(&vectors, d, false);
        let fp16 = 128 * d * 2;
        let ratio = idx.memory_bytes() as f64 / fp16 as f64;
        assert!((ratio - 3.875 / 16.0).abs() < 1e-6, "ratio {ratio}");
    }

    #[test]
    fn recall_beats_random_baseline_strongly() {
        // Random top-10 of 512 would get recall ≈ 10/512 ≈ 0.02.
        let d = 32;
        let vectors = dataset(512, d, 7);
        let idx = PolarIndex::build(&vectors, d, false);
        let mut rng = Pcg64::new(8);
        let mut q = vec![0.0f32; d];
        rng.fill_gaussian(&mut q);
        let approx = idx.search(&q, 10);
        let exact = exact_topk(&vectors, d, &q, 10);
        assert!(recall_at_k(&approx, &exact) > 0.4);
    }
}
