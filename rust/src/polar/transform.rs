//! Recursive Cartesian↔polar transform (paper Definition 1 / Algorithm 1,
//! `Polar` and the reconstruction inside `DeQuant`).
//!
//! A d-vector (d a power of two) is reduced over `levels` rounds: each
//! round pairs adjacent entries of the current radius vector, emitting an
//! angle per pair and halving the radius vector. After L levels a block of
//! 2^L coordinates is represented by one radius plus 2^L − 1 angles
//! (2^{L-1} at level 1, …, 1 at level L).
//!
//! Level-1 angles use atan2 in [0, 2π) (the paired values are signed);
//! level-≥2 angles pair *norms* (non-negative), so they lie in [0, π/2].
//! The paper's practical setting is L = 4 → blocks of 16 (§4.1); the full
//! recursion L = log₂ d is also supported (Theorem 1 experiments).

use std::f32::consts::PI;

/// Output of the forward transform on one vector.
#[derive(Clone, Debug, PartialEq)]
pub struct PolarRep {
    /// Residual radii, length d / 2^levels.
    pub radii: Vec<f32>,
    /// `angles[l]` holds the level-(l+1) angles, length d / 2^(l+1).
    pub angles: Vec<Vec<f32>>,
}

impl PolarRep {
    pub fn levels(&self) -> usize {
        self.angles.len()
    }

    pub fn dim(&self) -> usize {
        self.radii.len() << self.angles.len()
    }

    /// Total number of angles (d − d/2^L).
    pub fn num_angles(&self) -> usize {
        self.angles.iter().map(|a| a.len()).sum()
    }
}

/// Number of angles at level `l` (1-based) for dimension `d`.
pub fn angles_at_level(d: usize, l: usize) -> usize {
    d >> l
}

/// Forward transform (Algorithm 1, `Polar`): `x.len()` must be divisible by
/// 2^levels.
pub fn polar_forward(x: &[f32], levels: usize) -> PolarRep {
    let d = x.len();
    assert!(levels >= 1, "need at least one level");
    assert!(
        d % (1 << levels) == 0 && d >= (1 << levels),
        "dim {d} not divisible by 2^{levels}"
    );
    let mut angles = Vec::with_capacity(levels);

    // Level 1: signed pairs → atan2 in [0, 2π), radius = hypot.
    let mut r: Vec<f32> = Vec::with_capacity(d / 2);
    let mut a1: Vec<f32> = Vec::with_capacity(d / 2);
    for j in 0..d / 2 {
        let x0 = x[2 * j];
        let x1 = x[2 * j + 1];
        let mut theta = x1.atan2(x0); // (−π, π]
        if theta < 0.0 {
            theta += 2.0 * PI;
        }
        a1.push(theta);
        r.push(x0.hypot(x1));
    }
    angles.push(a1);

    // Levels 2..=L: non-negative pairs → atan in [0, π/2].
    for _l in 2..=levels {
        let half = r.len() / 2;
        let mut nr = Vec::with_capacity(half);
        let mut al = Vec::with_capacity(half);
        for j in 0..half {
            let r0 = r[2 * j];
            let r1 = r[2 * j + 1];
            // atan2 of non-negatives lies in [0, π/2]; also handles r0=0.
            al.push(r1.atan2(r0));
            nr.push(r0.hypot(r1));
        }
        angles.push(al);
        r = nr;
    }

    PolarRep { radii: r, angles }
}

/// Inverse transform: reconstruct the Cartesian vector from radii + angles.
pub fn polar_inverse(rep: &PolarRep, out: &mut [f32]) {
    let levels = rep.levels();
    assert_eq!(out.len(), rep.dim(), "output buffer size");
    // Expand radii top-down.
    let mut r = rep.radii.clone();
    for l in (2..=levels).rev() {
        let al = &rep.angles[l - 1];
        let mut nr = Vec::with_capacity(r.len() * 2);
        for (j, &radius) in r.iter().enumerate() {
            let (s, c) = al[j].sin_cos();
            nr.push(radius * c);
            nr.push(radius * s);
        }
        r = nr;
    }
    // Level 1 → Cartesian.
    let a1 = &rep.angles[0];
    for (j, &radius) in r.iter().enumerate() {
        let (s, c) = a1[j].sin_cos();
        out[2 * j] = radius * c;
        out[2 * j + 1] = radius * s;
    }
}

/// Convenience: forward + immediate inverse (used in tests/benches).
pub fn roundtrip(x: &[f32], levels: usize) -> Vec<f32> {
    let rep = polar_forward(x, levels);
    let mut out = vec![0.0f32; x.len()];
    polar_inverse(&rep, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::norm2;
    use crate::util::rng::{Pcg64, Rng};

    #[test]
    fn shapes_follow_definition_1() {
        // d = 16, L = 4: angles per level 8, 4, 2, 1; one radius.
        let x: Vec<f32> = (0..16).map(|i| (i as f32) - 7.5).collect();
        let rep = polar_forward(&x, 4);
        assert_eq!(rep.radii.len(), 1);
        let lens: Vec<usize> = rep.angles.iter().map(|a| a.len()).collect();
        assert_eq!(lens, vec![8, 4, 2, 1]);
        assert_eq!(rep.num_angles(), 15);
        assert_eq!(rep.dim(), 16);
    }

    #[test]
    fn partial_levels_shapes() {
        // d = 64, L = 2 → 16 radii; angles 32, 16.
        let x = vec![1.0f32; 64];
        let rep = polar_forward(&x, 2);
        assert_eq!(rep.radii.len(), 16);
        assert_eq!(rep.angles[0].len(), 32);
        assert_eq!(rep.angles[1].len(), 16);
    }

    #[test]
    fn angle_ranges_match_paper() {
        let mut rng = Pcg64::new(5);
        for _ in 0..200 {
            let mut x = vec![0.0f32; 32];
            rng.fill_gaussian(&mut x);
            let rep = polar_forward(&x, 5);
            for &a in &rep.angles[0] {
                assert!((0.0..2.0 * PI).contains(&a), "level-1 angle {a}");
            }
            for l in 1..rep.levels() {
                for &a in &rep.angles[l] {
                    assert!(
                        (0.0..=PI / 2.0 + 1e-6).contains(&a),
                        "level-{} angle {a}",
                        l + 1
                    );
                }
            }
        }
    }

    #[test]
    fn radius_preserves_norm() {
        let mut rng = Pcg64::new(6);
        let mut x = vec![0.0f32; 64];
        rng.fill_gaussian(&mut x);
        let rep = polar_forward(&x, 6); // full recursion
        assert_eq!(rep.radii.len(), 1);
        assert!((rep.radii[0] - norm2(&x)).abs() < 1e-4);
    }

    #[test]
    fn exact_roundtrip_random_vectors() {
        let mut rng = Pcg64::new(7);
        for &(d, l) in &[(4usize, 1usize), (4, 2), (16, 4), (64, 4), (128, 4), (64, 6)] {
            for _ in 0..20 {
                let mut x = vec![0.0f32; d];
                rng.fill_gaussian(&mut x);
                let y = roundtrip(&x, l);
                for (a, b) in x.iter().zip(&y) {
                    assert!((a - b).abs() < 1e-4, "d={d} l={l}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn roundtrip_handles_zeros_and_axis_vectors() {
        // Degenerate inputs: zero vector, single-coordinate spikes, negatives.
        let cases: Vec<Vec<f32>> = vec![
            vec![0.0; 16],
            {
                let mut v = vec![0.0; 16];
                v[0] = 3.0;
                v
            },
            {
                let mut v = vec![0.0; 16];
                v[15] = -2.5;
                v
            },
            vec![-1.0; 16],
        ];
        for x in cases {
            let y = roundtrip(&x, 4);
            for (a, b) in x.iter().zip(&y) {
                assert!((a - b).abs() < 1e-5, "{x:?} → {y:?}");
            }
        }
    }

    #[test]
    fn reconstruction_formula_spot_check() {
        // Verify the closed-form in Definition 1 for one coordinate:
        // x_0 = r · Π cos(first angle of each level).
        let mut rng = Pcg64::new(8);
        let mut x = vec![0.0f32; 16];
        rng.fill_gaussian(&mut x);
        let rep = polar_forward(&x, 4);
        let mut acc = rep.radii[0];
        for l in (0..4).rev() {
            acc *= rep.angles[l][0].cos();
        }
        assert!((acc - x[0]).abs() < 1e-4);
    }

    #[test]
    #[should_panic]
    fn rejects_indivisible_dims() {
        polar_forward(&[1.0, 2.0, 3.0], 1);
    }
}
