//! Cross-worker prefix directory: route anonymous traffic onto warm pages.
//!
//! Router session-affinity only helps requests that carry a session key;
//! anonymous traffic sharing a system prompt or few-shot preamble lands
//! on whichever replica the spread policy picks and re-prefills cold.
//! PolarQuant's normalization-free slots make cached pages freely
//! shareable, so the only missing piece is *knowing where they are*:
//! each worker's scheduler publishes compact fingerprints of its radix
//! paths here, and the [`Router`](crate::coordinator::router::Router)
//! consults the directory to send a session-less request to the worker
//! advertising the longest matching fingerprint chain.
//!
//! Fingerprints are chained rolling hashes, one per page-aligned token
//! chunk: the hash state carries across chunks, so the fingerprint at
//! depth `d` identifies the entire `d`-page prefix, and one
//! `(method, fingerprint)` key is all a lookup needs per depth. Entries
//! are per-codec (`method`-keyed) because pages hold encoded bytes and
//! never match across codecs.
//!
//! Consistency model: the directory is *advisory*. Advertisements are
//! reference-counted per worker against radix-node lifetimes — a node
//! advertises exactly the depths its own edge covers when it gains
//! fresh pages, and retracts them when it is truly evicted; splits move
//! pages between nodes without changing coverage, and tier demotion
//! keeps the entry advertised (a spilled leaf is still matchable — it
//! promotes back on the hit). Workers flush publish events after every
//! scheduler tick, so the directory may briefly lag the trees in either
//! direction. A stale direction is therefore *never* an error: the
//! routed worker just misses (or part-misses) in its radix tree and
//! prefills the difference, exactly like any cold request — the
//! scheduler counts those as `stale_hits` so the lag is observable.

use crate::util::hash::{fnv1a, FNV1A_SEED};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// One radix-tree mutation to replay into the directory: a node gained
/// fresh pages (`retract == false`) or was truly evicted
/// (`retract == true`). `tokens` is the full root-to-node token path
/// (page-aligned by construction) and `pages` the node's own edge pages
/// — the event covers the deepest `pages` page-depths of `tokens`.
#[derive(Clone, Debug)]
pub struct DirEvent {
    pub retract: bool,
    pub tokens: Vec<u32>,
    pub pages: usize,
}

/// Per-fingerprint advertisers: worker index → reference count. Counts
/// are per radix node, so a worker's entry dies exactly when its last
/// node covering that prefix depth does.
type WorkerCounts = BTreeMap<usize, u32>;

/// All advertised fingerprints of one codec's trees.
type FpTable = BTreeMap<u64, WorkerCounts>;

/// The shared cross-worker prefix directory. Thread-safe; one instance
/// is shared by the router and every worker's scheduler.
pub struct PrefixDirectory {
    page_tokens: usize,
    tables: Mutex<BTreeMap<String, FpTable>>,
}

impl PrefixDirectory {
    pub fn new(page_tokens: usize) -> Self {
        assert!(page_tokens > 0);
        Self { page_tokens, tables: Mutex::new(BTreeMap::new()) }
    }

    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Chained FNV-1a fingerprints, one per *full* page chunk of
    /// `tokens`. The hash state rolls across chunks, so `fps[d-1]`
    /// commits to the whole `d`-page prefix.
    pub fn fingerprints(&self, tokens: &[u32]) -> Vec<u64> {
        let mut fps = Vec::with_capacity(tokens.len() / self.page_tokens);
        let mut h = FNV1A_SEED;
        for chunk in tokens.chunks_exact(self.page_tokens) {
            for t in chunk {
                h = fnv1a(h, &t.to_le_bytes());
            }
            fps.push(h);
        }
        fps
    }

    /// Advertise/retract under an already-held table lock (the flush
    /// path batches a whole tick's events into one acquisition).
    fn apply_locked(
        &self,
        tables: &mut BTreeMap<String, FpTable>,
        worker: usize,
        method: &str,
        tokens: &[u32],
        own_pages: usize,
        retract: bool,
    ) {
        let fps = self.fingerprints(tokens);
        let total = fps.len();
        let own = own_pages.min(total);
        if !retract {
            let table = tables.entry(method.to_string()).or_default();
            for fp in &fps[total - own..] {
                *table.entry(*fp).or_default().entry(worker).or_insert(0) += 1;
            }
            return;
        }
        // Unknown entries are ignored on retract (the directory may
        // have been created after the node was).
        let Some(table) = tables.get_mut(method) else {
            return;
        };
        for fp in &fps[total - own..] {
            if let Some(counts) = table.get_mut(fp) {
                if let Some(c) = counts.get_mut(&worker) {
                    *c -= 1;
                    if *c == 0 {
                        counts.remove(&worker);
                    }
                }
                if counts.is_empty() {
                    table.remove(fp);
                }
            }
        }
        if table.is_empty() {
            tables.remove(method);
        }
    }

    /// Advertise the deepest `own_pages` page-depths of `tokens` for
    /// `worker` (the depths a freshly inserted radix node covers; its
    /// ancestors advertised theirs at their own insert).
    pub fn advertise(&self, worker: usize, method: &str, tokens: &[u32], own_pages: usize) {
        let mut tables = self.tables.lock().unwrap();
        self.apply_locked(&mut tables, worker, method, tokens, own_pages, false);
    }

    /// Retract what [`advertise`](Self::advertise) published for a now
    /// truly-evicted node.
    pub fn retract(&self, worker: usize, method: &str, tokens: &[u32], own_pages: usize) {
        let mut tables = self.tables.lock().unwrap();
        self.apply_locked(&mut tables, worker, method, tokens, own_pages, true);
    }

    /// Replay one drained radix event for `worker`.
    pub fn apply(&self, worker: usize, method: &str, ev: &DirEvent) {
        let mut tables = self.tables.lock().unwrap();
        self.apply_locked(&mut tables, worker, method, &ev.tokens, ev.pages, ev.retract);
    }

    /// Replay a whole tick's drained events for `worker` under ONE lock
    /// acquisition; returns the live entry count (the gauge) so the
    /// caller doesn't need a second acquisition either. The routing
    /// path contends on this same lock, so the flush must not take it
    /// once per event.
    pub fn apply_batch(&self, worker: usize, events: &[(String, DirEvent)]) -> usize {
        let mut tables = self.tables.lock().unwrap();
        for (method, ev) in events {
            self.apply_locked(&mut tables, worker, method, &ev.tokens, ev.pages, ev.retract);
        }
        tables.values().map(|t| t.len()).sum()
    }

    /// Deepest advertised prefix of `prompt` under `method`'s codec:
    /// `(matched_tokens, advertising workers)`, or `None` on a miss.
    /// Walked deepest-first so the first hit is the longest chain.
    pub fn lookup(&self, method: &str, prompt: &[u32]) -> Option<(usize, Vec<usize>)> {
        let fps = self.fingerprints(prompt);
        let tables = self.tables.lock().unwrap();
        let table = tables.get(method)?;
        for (d, fp) in fps.iter().enumerate().rev() {
            if let Some(counts) = table.get(fp) {
                if !counts.is_empty() {
                    let workers = counts.keys().copied().collect();
                    return Some(((d + 1) * self.page_tokens, workers));
                }
            }
        }
        None
    }

    /// Live `(method, fingerprint)` entries across all codecs — the
    /// `prefix_routing.directory_entries` gauge.
    pub fn entries(&self) -> usize {
        self.tables.lock().unwrap().values().map(|t| t.len()).sum()
    }

    /// Test/debug view of one codec's table: fingerprint → advertising
    /// workers, refcounts collapsed.
    pub fn table_snapshot(&self, method: &str) -> BTreeMap<u64, Vec<usize>> {
        self.tables
            .lock()
            .unwrap()
            .get(method)
            .map(|t| {
                t.iter()
                    .map(|(fp, counts)| (*fp, counts.keys().copied().collect()))
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const M: &str = "polarquant-r-offline";

    fn prompt(head: u32, pages: usize, pt: usize) -> Vec<u32> {
        (0..pages * pt).map(|i| head * 1000 + i as u32).collect()
    }

    #[test]
    fn fingerprints_chain_across_pages() {
        let d = PrefixDirectory::new(4);
        let a = d.fingerprints(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let b = d.fingerprints(&[1, 2, 3, 4, 9, 9, 9, 9]);
        assert_eq!(a.len(), 2);
        assert_eq!(a[0], b[0], "same first page, same depth-1 fp");
        assert_ne!(a[1], b[1], "depth-2 fp commits to both pages");
        // Partial pages contribute nothing.
        assert_eq!(d.fingerprints(&[1, 2, 3]).len(), 0);
        assert_eq!(d.fingerprints(&[1, 2, 3, 4, 5]).len(), 1);
    }

    #[test]
    fn longest_chain_wins_and_misses_are_none() {
        let d = PrefixDirectory::new(4);
        let p = prompt(1, 3, 4);
        d.advertise(0, M, &p[..8], 2); // worker 0: 2 pages deep
        d.advertise(1, M, &p, 3); // worker 1: all 3 pages
        let (tokens, workers) = d.lookup(M, &p).unwrap();
        assert_eq!(tokens, 12);
        assert_eq!(workers, vec![1], "deepest advertiser wins");
        // A prompt sharing only the first page matches at depth 1.
        let mut q = p[..4].to_vec();
        q.extend([7; 8]);
        let (tokens, workers) = d.lookup(M, &q).unwrap();
        assert_eq!(tokens, 4);
        assert_eq!(workers, vec![0, 1]);
        assert!(d.lookup(M, &prompt(9, 2, 4)).is_none(), "unknown prefix");
        assert!(d.lookup("exact", &p).is_none(), "codecs never cross-match");
    }

    #[test]
    fn own_pages_scopes_the_advertisement_to_one_node() {
        // A child node inserted under a 2-page ancestor advertises only
        // its own deeper depths; the ancestor's depths came from its own
        // insert. Retracting the child leaves the ancestor advertised.
        let d = PrefixDirectory::new(4);
        let p = prompt(3, 3, 4);
        d.advertise(0, M, &p[..8], 2); // ancestor: depths 1..=2
        d.advertise(0, M, &p, 1); // leaf: depth 3 only
        assert_eq!(d.entries(), 3);
        d.retract(0, M, &p, 1);
        let (tokens, _) = d.lookup(M, &p).unwrap();
        assert_eq!(tokens, 8, "ancestor depths survive the leaf retract");
        d.retract(0, M, &p[..8], 2);
        assert!(d.lookup(M, &p).is_none());
        assert_eq!(d.entries(), 0, "fully retracted");
    }

    #[test]
    fn refcounts_survive_double_advertise() {
        // Two nodes of the same worker can cover the same depth only via
        // hash collision, but other workers routinely share depths; the
        // per-worker counts keep retraction exact either way.
        let d = PrefixDirectory::new(4);
        let p = prompt(5, 2, 4);
        d.advertise(0, M, &p, 2);
        d.advertise(1, M, &p, 2);
        d.retract(0, M, &p, 2);
        let (_, workers) = d.lookup(M, &p).unwrap();
        assert_eq!(workers, vec![1]);
        // Retracting something never advertised is a no-op.
        d.retract(3, M, &prompt(8, 2, 4), 2);
        assert_eq!(d.entries(), 2);
    }
}
