//! Prefix cache: cross-request zero-copy reuse of encoded prompt pages.
//!
//! Serving traffic is dominated by shared prompt prefixes — system
//! prompts, few-shot headers, growing multi-turn histories. Because
//! page-native codec slots are self-contained (PolarQuant pages are pure
//! packed angle codes with no per-block scale/zero-point metadata), a
//! cached prefix page is reusable as-is by any request whose prompt
//! starts with those tokens, so a prefix cache holds strictly more
//! reusable tokens per byte than scale/offset codecs.
//!
//! * [`radix`] — the radix tree keyed on token-id page chunks whose
//!   leaves reference pages in [`crate::kvcache::paged::PagedPool`], with
//!   per-node pins (active sequences), copy-on-write splits on
//!   divergence, and an O(log n) LRU eviction index over cold
//!   unreferenced leaves.
//! * [`PrefixCacheSet`] — one radix tree **per page codec**: pool pages
//!   hold encoded bytes now, so a prefix written by `polarquant` must
//!   never be matched by an `exact` request. The set routes
//!   match/insert/pin by method name and spreads eviction pressure
//!   across trees.
//!
//! The scheduler consults the set at admission (longest cached prefix →
//! shared pages + skipped prefill), inserts every admitted page-codec
//! prompt, and pins the matched path for the sequence's lifetime. There
//! is no second engine-side store: a radix hit hands the engine already-
//! encoded pool pages, which it reads back through the codec — control
//! plane and data plane reference the same bytes.

pub mod radix;

pub use radix::{NodeId, PrefixConfig, PrefixMatch, PrefixStats, RadixPrefixCache};

use crate::kvcache::paged::PagedPool;
use std::collections::BTreeMap;

/// Per-codec radix trees behind one facade. `max_pages` in the config is
/// a **global** budget across all trees; [`enforce_budget`] trims the
/// fattest tree first. LRU is per-tree (each tree keeps its own clock),
/// which is exact for single-method traffic and a fair round-robin
/// approximation across methods.
///
/// [`enforce_budget`]: PrefixCacheSet::enforce_budget
pub struct PrefixCacheSet {
    cfg: PrefixConfig,
    trees: BTreeMap<String, RadixPrefixCache>,
    /// Bumped on every insert; lets a gated admission detect that the
    /// tree grew between gating and admission (another batch member
    /// published its prompt) and re-match instead of using the stale
    /// gate-time match.
    epoch: u64,
}

impl PrefixCacheSet {
    pub fn new(cfg: PrefixConfig) -> Self {
        Self { cfg, trees: BTreeMap::new(), epoch: 0 }
    }

    /// Monotonic insert counter (see the `epoch` field).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    fn tree_mut(&mut self, method: &str) -> &mut RadixPrefixCache {
        let cfg = self.cfg.clone();
        self.trees
            .entry(method.to_string())
            .or_insert_with(|| RadixPrefixCache::new(cfg))
    }

    /// Longest cached prefix of `tokens` among pages encoded by
    /// `method`'s codec. An empty match when the method has no tree yet.
    pub fn match_prefix(&mut self, method: &str, tokens: &[u32]) -> PrefixMatch {
        match self.trees.get_mut(method) {
            Some(t) => t.match_prefix(tokens),
            None => PrefixMatch { pages: Vec::new(), tokens: 0, node: None },
        }
    }

    pub fn pin(&mut self, method: &str, node: NodeId) {
        if let Some(t) = self.trees.get_mut(method) {
            t.pin(node);
        }
    }

    pub fn unpin(&mut self, method: &str, node: NodeId) {
        if let Some(t) = self.trees.get_mut(method) {
            t.unpin(node);
        }
    }

    /// Insert the page-aligned prefix of `tokens` into `method`'s tree.
    pub fn insert(
        &mut self,
        method: &str,
        tokens: &[u32],
        pool: &mut PagedPool,
        src_seq: u64,
    ) -> Option<NodeId> {
        self.epoch += 1;
        self.tree_mut(method).insert(tokens, pool, src_seq)
    }

    /// Pool pages referenced across all trees.
    pub fn cached_pages(&self) -> usize {
        self.trees.values().map(|t| t.cached_pages()).sum()
    }

    /// Cumulative evicted nodes across all trees (monotonic).
    pub fn evicted_nodes(&self) -> u64 {
        self.trees.values().map(|t| t.stats().evicted_nodes).sum()
    }

    /// Pool pages eviction could free right now, across all trees.
    pub fn freeable_pages(&self, pool: &PagedPool) -> usize {
        self.trees.values().map(|t| t.freeable_pages(pool)).sum()
    }

    /// Free at least `pages_needed` pool pages by evicting cache entries
    /// across trees — or do nothing at all (all-or-nothing, like
    /// [`RadixPrefixCache::make_room`]).
    pub fn make_room(&mut self, pool: &mut PagedPool, pages_needed: usize) -> bool {
        if pages_needed == 0 {
            return true;
        }
        if self.freeable_pages(pool) < pages_needed {
            return false;
        }
        let mut freed = 0;
        for t in self.trees.values_mut() {
            if freed >= pages_needed {
                break;
            }
            freed += t.evict_lru(pool, pages_needed - freed);
        }
        // Fallback: cascaded eviction of unpinned subtrees whose pages
        // only free once their last sharer retires.
        while freed < pages_needed {
            let mut any = false;
            for t in self.trees.values_mut() {
                if freed >= pages_needed {
                    break;
                }
                if let Some(f) = t.evict_one_node(pool) {
                    freed += f;
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        freed >= pages_needed
    }

    /// Trim back under the global `max_pages` budget, evicting from the
    /// tree holding the most pages first.
    pub fn enforce_budget(&mut self, pool: &mut PagedPool) {
        while self.cached_pages() > self.cfg.max_pages {
            let mut order: Vec<&mut RadixPrefixCache> = self.trees.values_mut().collect();
            order.sort_by_key(|t| std::cmp::Reverse(t.cached_pages()));
            let mut evicted = false;
            for t in order {
                if t.evict_one_node(pool).is_some() {
                    evicted = true;
                    break;
                }
            }
            if !evicted {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::paged::PagedConfig;

    fn pool(pages: usize) -> PagedPool {
        PagedPool::new(PagedConfig { page_tokens: 4, token_bytes: 2, num_pages: pages })
    }

    fn set(max_pages: usize) -> PrefixCacheSet {
        PrefixCacheSet::new(PrefixConfig { page_tokens: 4, max_pages })
    }

    #[test]
    fn methods_never_share_prefixes() {
        let (mut s, mut p) = (set(64), pool(32));
        let prompt: Vec<u32> = vec![7; 8];
        p.register(1, 8).unwrap();
        s.insert("polarquant", &prompt, &mut p, 1);
        assert_eq!(s.match_prefix("polarquant", &prompt).tokens, 8);
        assert_eq!(
            s.match_prefix("exact", &prompt).tokens,
            0,
            "codec-mismatched pages must not match"
        );
        p.release(1).unwrap();
    }

    #[test]
    fn global_budget_spans_trees() {
        let (mut s, mut p) = (set(2), pool(32));
        p.register(1, 8).unwrap();
        p.register(2, 8).unwrap();
        s.insert("exact", &[1; 8], &mut p, 1);
        s.insert("fp16", &[2; 8], &mut p, 2);
        assert_eq!(s.cached_pages(), 4);
        p.release(1).unwrap();
        p.release(2).unwrap();
        s.enforce_budget(&mut p);
        assert!(s.cached_pages() <= 2, "global budget: {}", s.cached_pages());
    }

    #[test]
    fn make_room_is_all_or_nothing_across_trees() {
        let (mut s, mut p) = (set(64), pool(16));
        p.register(1, 8).unwrap();
        p.register(2, 8).unwrap();
        let na = s.insert("exact", &[1; 8], &mut p, 1);
        s.insert("kivi", &[2; 8], &mut p, 2);
        p.release(1).unwrap();
        p.release(2).unwrap();
        s.pin("exact", na.unwrap());
        assert_eq!(s.freeable_pages(&p), 2, "only the kivi entry is free");
        assert!(!s.make_room(&mut p, 3), "cannot cover: nothing evicted");
        assert_eq!(s.cached_pages(), 4);
        assert!(s.make_room(&mut p, 2));
        assert_eq!(s.match_prefix("kivi", &[2; 8]).tokens, 0);
        assert_eq!(s.match_prefix("exact", &[1; 8]).tokens, 8, "pinned survives");
    }
}
