//! Prefix cache: cross-request zero-copy reuse of encoded prompt pages.
//!
//! Serving traffic is dominated by shared prompt prefixes — system
//! prompts, few-shot headers, growing multi-turn histories. Because
//! page-native codec slots are self-contained (PolarQuant pages are pure
//! packed angle codes with no per-block scale/zero-point metadata), a
//! cached prefix page is reusable as-is by any request whose prompt
//! starts with those tokens, so a prefix cache holds strictly more
//! reusable tokens per byte than scale/offset codecs.
//!
//! * [`radix`] — the radix tree keyed on token-id page chunks whose
//!   leaves reference pages in [`crate::kvcache::paged::PagedPool`], with
//!   per-node pins (active sequences), copy-on-write splits on
//!   divergence, and an O(log n) LRU eviction index over cold
//!   unreferenced leaves.
//! * [`PrefixCacheSet`] — one radix tree **per page codec**, each over
//!   its codec's own codec-sized pool
//!   ([`crate::kvcache::pools::PoolSet`]): pages hold encoded bytes, so
//!   a prefix written by `polarquant` must never be matched by an
//!   `exact` request — and since pools are now per-codec, each tree
//!   references pages of its own size class. The set routes
//!   match/insert/pin/make-room by method name and enforces a **global
//!   byte budget** across trees (pages of different trees have
//!   different byte sizes, so a page-count budget would be
//!   apples-to-oranges).
//!
//! The scheduler consults the set at admission (longest cached prefix →
//! shared pages + skipped prefill), inserts every admitted page-codec
//! prompt, and pins the matched path for the sequence's lifetime. There
//! is no second engine-side store: a radix hit hands the engine already-
//! encoded pool pages, which it reads back through the codec — control
//! plane and data plane reference the same bytes.

pub mod directory;
pub mod radix;

pub use directory::{DirEvent, PrefixDirectory};
pub use radix::{NodeId, PageRef, PrefixConfig, PrefixMatch, PrefixStats, RadixPrefixCache};

use crate::kvcache::paged::PagedPool;
use crate::kvcache::pools::PoolSet;
use crate::kvcache::tier::DiskExtent;
use std::collections::BTreeMap;

/// Per-codec radix trees behind one facade. The budget is in **bytes**
/// across all trees; [`enforce_budget`] trims the tree holding the most
/// resident bytes first. The set owns one **shared monotonic LRU
/// clock**: every match/insert stamps nodes from the same counter
/// regardless of tree, so cross-codec recency comparisons — in
/// particular the disk tier's "globally coldest first" demotion order —
/// are exact rather than per-tree approximate.
///
/// [`enforce_budget`]: PrefixCacheSet::enforce_budget
pub struct PrefixCacheSet {
    page_tokens: usize,
    /// Global budget on pool bytes the cache keeps referenced.
    max_bytes: usize,
    trees: BTreeMap<String, RadixPrefixCache>,
    /// Bumped on every insert; lets a gated admission detect that the
    /// tree grew between gating and admission (another batch member
    /// published its prompt) and re-match instead of using the stale
    /// gate-time match.
    epoch: u64,
    /// The shared LRU clock spanning all trees.
    clock: u64,
    /// Whether trees log [`DirEvent`]s for the cross-worker prefix
    /// directory (set when the scheduler attaches one).
    publish: bool,
}

impl PrefixCacheSet {
    pub fn new(page_tokens: usize, max_bytes: usize) -> Self {
        Self {
            page_tokens,
            max_bytes,
            trees: BTreeMap::new(),
            epoch: 0,
            clock: 0,
            publish: false,
        }
    }

    /// Enable directory-event logging on every tree, present and future.
    pub fn set_publish(&mut self, on: bool) {
        self.publish = on;
        for t in self.trees.values_mut() {
            t.set_publish(on);
        }
    }

    /// Drain `(method, event)` pairs accumulated across all trees since
    /// the last call, for replay into a [`PrefixDirectory`].
    pub fn take_dir_events(&mut self) -> Vec<(String, DirEvent)> {
        let mut out = Vec::new();
        for (m, t) in self.trees.iter_mut() {
            for ev in t.take_dir_events() {
                out.push((m.clone(), ev));
            }
        }
        out
    }

    /// Monotonic insert counter (see the `epoch` field).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    fn tree_mut(&mut self, method: &str) -> &mut RadixPrefixCache {
        let cfg = PrefixConfig {
            page_tokens: self.page_tokens,
            // Per-tree page budgets are meaningless across size classes;
            // the set enforces the global byte budget instead.
            max_pages: usize::MAX,
        };
        let publish = self.publish;
        self.trees.entry(method.to_string()).or_insert_with(|| {
            let mut t = RadixPrefixCache::new(cfg);
            t.set_publish(publish);
            t
        })
    }

    /// Longest cached prefix of `tokens` among pages encoded by
    /// `method`'s codec. An empty match when the method has no tree yet.
    pub fn match_prefix(&mut self, method: &str, tokens: &[u32]) -> PrefixMatch {
        let clock = self.tick();
        match self.trees.get_mut(method) {
            Some(t) => t.match_prefix_at(tokens, clock),
            None => PrefixMatch::default(),
        }
    }

    pub fn pin(&mut self, method: &str, node: NodeId) {
        if let Some(t) = self.trees.get_mut(method) {
            t.pin(node);
        }
    }

    pub fn unpin(&mut self, method: &str, node: NodeId) {
        if let Some(t) = self.trees.get_mut(method) {
            t.unpin(node);
        }
    }

    /// Insert the page-aligned prefix of `tokens` into `method`'s tree,
    /// referencing pages of `method`'s own pool.
    pub fn insert(
        &mut self,
        method: &str,
        tokens: &[u32],
        pool: &mut PagedPool,
        src_seq: u64,
    ) -> Option<NodeId> {
        self.epoch += 1;
        let clock = self.tick();
        self.tree_mut(method).insert_at(tokens, pool, src_seq, clock)
    }

    /// RAM pool pages referenced across all trees (pages of different
    /// trees have different byte sizes; see
    /// [`cached_bytes`](Self::cached_bytes)).
    pub fn cached_pages(&self) -> usize {
        self.trees.values().map(|t| t.cached_pages()).sum()
    }

    /// Pages spilled to the disk tier across all trees.
    pub fn disk_pages(&self) -> usize {
        self.trees.values().map(|t| t.disk_pages()).sum()
    }

    /// Methods that currently have a tree (the scheduler's iteration
    /// surface for watermark demotion).
    pub fn tree_methods(&self) -> Vec<String> {
        self.trees.keys().cloned().collect()
    }

    /// Coldest evictable leaf of `method`'s tree (shared-clock stamp).
    pub fn coldest_evictable(&self, method: &str) -> Option<(u64, NodeId)> {
        self.trees.get(method).and_then(|t| t.coldest_evictable())
    }

    /// Coldest demotable leaf of `method`'s tree (see
    /// [`RadixPrefixCache::coldest_demotable`]).
    pub fn coldest_demotable(&self, method: &str, pool: &PagedPool) -> Option<(u64, NodeId)> {
        self.trees.get(method).and_then(|t| t.coldest_demotable(pool))
    }

    /// Demote one leaf of `method`'s tree to the disk tier.
    pub fn demote_node(
        &mut self,
        method: &str,
        id: NodeId,
        pool: &mut PagedPool,
        write: &mut dyn FnMut(&[u8]) -> Option<DiskExtent>,
    ) -> Option<usize> {
        self.trees.get_mut(method)?.demote_node(id, pool, write)
    }

    /// Promote one spilled node of `method`'s tree back into RAM pages;
    /// returns the extents for the caller to free in its tier store.
    pub fn promote_node(
        &mut self,
        method: &str,
        id: NodeId,
        pool: &mut PagedPool,
        read: &mut dyn FnMut(DiskExtent, &mut [u8]) -> bool,
    ) -> Option<Vec<DiskExtent>> {
        self.trees.get_mut(method)?.promote_node(id, pool, read)
    }

    /// Pages (RAM or disk) node `id` of `method`'s tree references.
    pub fn node_page_count(&self, method: &str, id: NodeId) -> usize {
        self.trees.get(method).map_or(0, |t| t.node_page_count(id))
    }

    /// Drain the extents of true-evicted disk nodes in `method`'s tree.
    pub fn take_dropped_extents(&mut self, method: &str) -> Vec<DiskExtent> {
        self.trees
            .get_mut(method)
            .map(|t| t.take_dropped_extents())
            .unwrap_or_default()
    }

    /// Evict one LRU leaf from `method`'s tree regardless of what it
    /// frees (budget pressure path). Returns pages freed.
    pub fn evict_one_node(&mut self, method: &str, pool: &mut PagedPool) -> Option<usize> {
        self.trees.get_mut(method)?.evict_one_node(pool)
    }

    /// Must-free eviction in `method`'s tree: evict LRU leaves until at
    /// least `pages_needed` pool pages are actually freed, skipping
    /// victims whose pages are all still shared with active sequences
    /// (evicting those would destroy reuse while reclaiming nothing).
    /// Returns pages freed.
    pub fn evict_lru(&mut self, method: &str, pool: &mut PagedPool, pages_needed: usize) -> usize {
        self.trees
            .get_mut(method)
            .map_or(0, |t| t.evict_lru(pool, pages_needed))
    }

    /// Resident bytes the cache references across all trees, each tree
    /// priced at its own pool's page size.
    pub fn cached_bytes(&self, pools: &PoolSet) -> usize {
        self.trees
            .iter()
            .map(|(m, t)| t.cached_pages() * pools.pool(m).map_or(0, |p| p.page_bytes()))
            .sum()
    }

    /// Cumulative evicted nodes across all trees (monotonic).
    pub fn evicted_nodes(&self) -> u64 {
        self.trees.values().map(|t| t.stats().evicted_nodes).sum()
    }

    /// Pool pages eviction could free right now in `method`'s pool.
    /// Only `method`'s own tree holds pages there — trees never cross
    /// codecs and every codec has its own pool — so cross-tree eviction
    /// cannot help a same-pool shortfall.
    pub fn freeable_pages(&self, method: &str, pool: &PagedPool) -> usize {
        self.trees.get(method).map_or(0, |t| t.freeable_pages(pool))
    }

    /// Free at least `pages_needed` pages in `method`'s pool by evicting
    /// that method's cache entries — or do nothing at all (all-or-
    /// nothing, like [`RadixPrefixCache::make_room`]).
    pub fn make_room(
        &mut self,
        method: &str,
        pool: &mut PagedPool,
        pages_needed: usize,
    ) -> bool {
        if pages_needed == 0 {
            return true;
        }
        match self.trees.get_mut(method) {
            Some(t) => t.make_room(pool, pages_needed),
            None => false,
        }
    }

    /// Trim back under the global byte budget, evicting from the tree
    /// holding the most resident bytes first (falling back to any tree
    /// that can evict when the fattest is fully pinned). Victims must
    /// hold RAM pages: the budget counts RAM bytes, so true-evicting a
    /// spilled (disk-resident) node would destroy tier-preserved state
    /// without freeing a single budget byte.
    pub fn enforce_budget(&mut self, pools: &mut PoolSet) {
        while self.cached_bytes(pools) > self.max_bytes {
            let mut order: Vec<(usize, String)> = self
                .trees
                .iter()
                .map(|(m, t)| {
                    let pb = pools.pool(m).map_or(0, |p| p.page_bytes());
                    (t.cached_pages() * pb, m.clone())
                })
                .collect();
            order.sort_by(|a, b| b.0.cmp(&a.0));
            let mut evicted = false;
            for (_, m) in order {
                let pool = pools.pool_mut(&m);
                if self.trees.get_mut(&m).unwrap().evict_one_ram_node(pool).is_some() {
                    evicted = true;
                    break;
                }
            }
            if !evicted {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    /// A codec-sized pool set over the tiny test model (page codecs get
    /// genuinely different page byte sizes).
    fn pools(pool_tokens: usize) -> PoolSet {
        PoolSet::for_model(&ModelConfig::test(), 4, pool_tokens)
    }

    fn set(max_bytes: usize) -> PrefixCacheSet {
        PrefixCacheSet::new(4, max_bytes)
    }

    #[test]
    fn methods_never_share_prefixes() {
        let mut s = set(1 << 20);
        let mut p = pools(128);
        let prompt: Vec<u32> = vec![7; 8];
        p.pool_mut("polarquant").register(1, 8).unwrap();
        let pool = p.pool_mut("polarquant");
        s.insert("polarquant", &prompt, pool, 1);
        assert_eq!(s.match_prefix("polarquant", &prompt).tokens, 8);
        assert_eq!(
            s.match_prefix("exact", &prompt).tokens,
            0,
            "codec-mismatched pages must not match"
        );
        p.release("polarquant", 1).unwrap();
    }

    #[test]
    fn budget_is_in_bytes_across_size_classes() {
        // Two trees over pools of different page sizes: the global
        // budget compares bytes, so the wide (exact) tree is trimmed
        // before the narrow (polar) one even with equal page counts.
        let mut p = pools(128);
        p.pool_mut("exact").register(1, 8).unwrap();
        p.pool_mut("polarquant").register(2, 8).unwrap();
        let exact_page = p.pool("exact").unwrap().page_bytes();
        let polar_page = p.pool("polarquant").unwrap().page_bytes();
        assert!(exact_page > polar_page, "size classes must differ");
        // Budget: exactly the polar entry's bytes.
        let mut s = set(2 * polar_page);
        s.insert("exact", &[1; 8], p.pool_mut("exact"), 1);
        s.insert("polarquant", &[2; 8], p.pool_mut("polarquant"), 2);
        assert_eq!(s.cached_bytes(&p), 2 * exact_page + 2 * polar_page);
        p.release("exact", 1).unwrap();
        p.release("polarquant", 2).unwrap();
        s.enforce_budget(&mut p);
        assert!(s.cached_bytes(&p) <= 2 * polar_page);
        assert_eq!(
            s.match_prefix("polarquant", &[2; 8]).tokens,
            8,
            "narrow entry survives; the wide one paid for the budget"
        );
        assert_eq!(s.match_prefix("exact", &[1; 8]).tokens, 0);
    }

    #[test]
    fn shared_clock_makes_cross_tree_coldness_comparable() {
        // One clock spans all trees: after touching the exact entry
        // last, the polar entry is the globally coldest — the per-tree
        // clocks this replaced could not order victims across codecs.
        let mut s = set(1 << 20);
        let mut p = pools(128);
        p.pool_mut("exact").register(1, 8).unwrap();
        p.pool_mut("polarquant").register(2, 8).unwrap();
        s.insert("exact", &[1; 8], p.pool_mut("exact"), 1);
        s.insert("polarquant", &[2; 8], p.pool_mut("polarquant"), 2);
        p.release("exact", 1).unwrap();
        p.release("polarquant", 2).unwrap();
        let (t_polar0, _) = s.coldest_evictable("polarquant").unwrap();
        let (t_exact0, _) = s.coldest_evictable("exact").unwrap();
        assert!(t_polar0 > t_exact0, "inserted later on the shared clock");
        // A lookup on the exact tree re-warms it past the polar entry.
        s.match_prefix("exact", &[1; 8]);
        let (t_exact, _) = s.coldest_evictable("exact").unwrap();
        let (t_polar, _) = s.coldest_evictable("polarquant").unwrap();
        assert!(
            t_polar < t_exact,
            "polar entry is globally coldest ({t_polar} vs {t_exact})"
        );
        // Demotability uses the same global stamps.
        let (t_demote, _) =
            s.coldest_demotable("polarquant", p.pool("polarquant").unwrap()).unwrap();
        assert_eq!(t_demote, t_polar);
    }

    #[test]
    fn make_room_is_all_or_nothing_per_method_pool() {
        let mut s = set(1 << 20);
        let mut p = pools(64);
        p.pool_mut("exact").register(1, 8).unwrap();
        p.pool_mut("kivi").register(2, 8).unwrap();
        let na = s.insert("exact", &[1; 8], p.pool_mut("exact"), 1);
        s.insert("kivi", &[2; 8], p.pool_mut("kivi"), 2);
        p.release("exact", 1).unwrap();
        p.release("kivi", 2).unwrap();
        s.pin("exact", na.unwrap());
        // Each pool only answers to its own tree now.
        assert_eq!(s.freeable_pages("kivi", p.pool("kivi").unwrap()), 2);
        assert_eq!(
            s.freeable_pages("exact", p.pool("exact").unwrap()),
            0,
            "pinned exact entry is not freeable"
        );
        assert!(
            !s.make_room("exact", p.pool_mut("exact"), 1),
            "kivi pages cannot cover an exact-pool shortfall"
        );
        assert_eq!(s.match_prefix("kivi", &[2; 8]).tokens, 8, "untouched");
        assert!(s.make_room("kivi", p.pool_mut("kivi"), 2));
        assert_eq!(s.match_prefix("kivi", &[2; 8]).tokens, 0);
        assert_eq!(s.match_prefix("exact", &[1; 8]).tokens, 8, "pinned survives");
    }
}
