//! Prefix cache: cross-request reuse of quantized prompt pages.
//!
//! Serving traffic is dominated by shared prompt prefixes — system
//! prompts, few-shot headers, growing multi-turn histories. Because
//! PolarQuant pages are pure packed angle codes with no per-block
//! scale/zero-point metadata, a cached prefix page is reusable as-is by
//! any request whose prompt starts with those tokens, so a prefix cache
//! holds strictly more reusable tokens per byte than scale/offset codecs.
//!
//! * [`radix`] — the radix tree keyed on token-id page chunks whose
//!   leaves reference pages in [`crate::kvcache::paged::PagedPool`], with
//!   per-node pins (active sequences), copy-on-write splits on
//!   divergence, and LRU eviction of cold unreferenced nodes.
//!
//! The scheduler consults the tree at admission (longest cached prefix →
//! shared pages + skipped prefill), inserts every admitted prompt, and
//! pins the matched path for the sequence's lifetime; the engine layer
//! mirrors the reuse decision with materialized K/V snapshots (see
//! `coordinator::worker`).

pub mod radix;

pub use radix::{NodeId, PrefixConfig, PrefixMatch, PrefixStats, RadixPrefixCache};
