//! Radix tree over token-id page chunks, the core of the prefix cache.
//!
//! Every edge covers a whole number of pool pages (`page_tokens` tokens
//! each) because a page is the smallest unit two sequences can share:
//! PolarQuant pages are pure packed codes with no per-block metadata, so
//! a cached page is reusable by any request whose prompt contains exactly
//! those tokens at those positions. Children are keyed by their edge's
//! first page chunk, which makes sibling edges that diverge inside their
//! first page ordinary siblings instead of a split case.
//!
//! The tree holds one pool reference per cached page (taken via
//! [`PagedPool::retain_page`]), so pages survive their originating
//! sequence. Divergence splits an edge at the page boundary
//! (copy-on-write at the tree level: both branches keep referencing the
//! common pages, and each branch owns its private diverging tail).
//! Nodes pinned by active sequences are never evicted; cold unpinned
//! leaves go first, in LRU order. Victim selection is O(log n): the
//! tree maintains an index of evictable leaves ordered by
//! (last_touch, id) — a `BTreeSet` standing in for an intrusive LRU
//! list — kept in sync at every touch/pin/link mutation, so `make_room`
//! bursts no longer rescan the whole node slab per eviction.

//! Tiered residency: a leaf's pages can be *demoted* to the disk tier
//! ([`PageRef::Disk`]) — bytes spilled, RAM pages freed, the entry kept
//! matchable — and *promoted* back into fresh pool pages on a match.
//! A node's pages are always uniformly RAM or uniformly disk (tier
//! moves are whole-leaf), so a match never stitches half-resident
//! edges; the tree never does I/O itself — demote/promote thread byte
//! closures from whoever owns the tier store.

use crate::kvcache::paged::{PagedPool, PageId};
use crate::kvcache::tier::DiskExtent;
use crate::prefix::directory::DirEvent;
use std::collections::{BTreeMap, BTreeSet};

/// Slab index of a node. The root is always node 0 with an empty edge.
pub type NodeId = usize;

/// Prefix-cache configuration.
#[derive(Clone, Debug)]
pub struct PrefixConfig {
    /// Must match the pool's `page_tokens`.
    pub page_tokens: usize,
    /// Soft budget on pool pages the cache keeps referenced; LRU eviction
    /// trims back down after inserts.
    pub max_pages: usize,
}

/// Cumulative cache statistics (monotonic counters).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    pub inserted_nodes: u64,
    pub evicted_nodes: u64,
}

/// A cached page's residency: a RAM pool page, or an extent spilled
/// into that codec's disk-tier segment. Slots are self-contained byte
/// blobs (PolarQuant carries no out-of-slot quantization state), so a
/// page moves between the variants by pure byte copy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PageRef {
    Ram(PageId),
    Disk(DiskExtent),
}

/// Result of a longest-prefix lookup.
#[derive(Debug, Default)]
pub struct PrefixMatch {
    /// Cached RAM pages covering the immediately usable head of the
    /// match, in order.
    pub pages: Vec<PageId>,
    /// Matched token count of the RAM head (`pages.len() * page_tokens`).
    pub tokens: usize,
    /// Deepest matched node — RAM or disk — to pin while the requesting
    /// sequence (or gate) is live; pinning it protects the whole path,
    /// demotion included, since tier moves only take unpinned leaves.
    /// `None` when nothing matched.
    pub node: Option<NodeId>,
    /// Matched-path nodes whose pages are spilled to the disk tier, in
    /// path order. Promote these (then re-match) to extend the usable
    /// head; without a tier they are unreachable bytes and the match
    /// truncates to `pages`.
    pub disk: Vec<NodeId>,
    /// Tokens the match additionally covers once `disk` is promoted.
    pub disk_tokens: usize,
}

struct Node {
    /// Edge label: `pages.len() * page_tokens` token ids (root: empty).
    tokens: Vec<u32>,
    pages: Vec<PageRef>,
    /// Children keyed by the first page chunk of their edge.
    children: BTreeMap<Vec<u32>, NodeId>,
    parent: NodeId,
    /// Active sequences currently relying on this node's pages.
    pins: u32,
    /// LRU clock value of the last lookup/insert that touched this node.
    last_touch: u64,
}

/// Which evictable leaves an eviction pass may take.
#[derive(Clone, Copy, PartialEq, Eq)]
enum VictimFilter {
    /// Any evictable leaf — the true-drop path of last resort.
    Any,
    /// Only victims with at least one last-reference RAM page (the
    /// make-room path: evicting a still-shared node destroys reusable
    /// state while reclaiming nothing).
    FreesRam,
    /// Only victims holding RAM pages (RAM-budget trims: a disk node
    /// costs no pool bytes, so destroying it cannot help the budget —
    /// it would only throw away spilled state the tier preserved).
    HoldsRam,
}

/// The radix-tree prefix cache.
pub struct RadixPrefixCache {
    cfg: PrefixConfig,
    nodes: Vec<Option<Node>>,
    free_nodes: Vec<NodeId>,
    clock: u64,
    cached_pages: usize,
    /// Pages currently spilled to the disk tier (extents referenced by
    /// nodes); disjoint from `cached_pages`, which counts RAM pages.
    disk_pages: usize,
    stats: PrefixStats,
    /// Extents of true-evicted disk nodes, held until the tier owner
    /// drains and frees them ([`take_dropped_extents`]).
    ///
    /// [`take_dropped_extents`]: Self::take_dropped_extents
    dropped_extents: Vec<DiskExtent>,
    /// Eviction index: exactly the evictable nodes (unpinned leaves),
    /// keyed by (last_touch, id) so `iter().next()` is the LRU victim.
    evictable_index: BTreeSet<(u64, NodeId)>,
    /// When set, node-lifetime changes are logged as [`DirEvent`]s for
    /// the cross-worker prefix directory: a node advertises the depths
    /// its own edge covers when it gains fresh pages and retracts them
    /// on true eviction. Splits move pages between nodes without
    /// changing total coverage (no event), and tier demotion keeps the
    /// entry advertised — a spilled leaf is still matchable.
    publish: bool,
    dir_events: Vec<DirEvent>,
}

impl RadixPrefixCache {
    pub fn new(cfg: PrefixConfig) -> Self {
        assert!(cfg.page_tokens > 0);
        let root = Node {
            tokens: Vec::new(),
            pages: Vec::new(),
            children: BTreeMap::new(),
            parent: 0,
            pins: 0,
            last_touch: 0,
        };
        Self {
            cfg,
            nodes: vec![Some(root)],
            free_nodes: Vec::new(),
            clock: 0,
            cached_pages: 0,
            disk_pages: 0,
            stats: PrefixStats::default(),
            dropped_extents: Vec::new(),
            evictable_index: BTreeSet::new(),
            publish: false,
            dir_events: Vec::new(),
        }
    }

    /// Enable (or disable) directory-event logging. Off by default so
    /// trees without a directory attached pay nothing and leak nothing.
    pub fn set_publish(&mut self, on: bool) {
        self.publish = on;
        if !on {
            self.dir_events.clear();
        }
    }

    /// Drain the directory events accumulated since the last call.
    pub fn take_dir_events(&mut self) -> Vec<DirEvent> {
        std::mem::take(&mut self.dir_events)
    }

    /// Full root-to-`id` token path (the concatenated edge labels);
    /// page-aligned by construction.
    pub fn token_path(&self, id: NodeId) -> Vec<u32> {
        let mut edges = Vec::new();
        let mut cur = id;
        while cur != 0 {
            let n = self.node(cur);
            edges.push(n.tokens.clone());
            cur = n.parent;
        }
        edges.reverse();
        edges.concat()
    }

    /// Live node ids, root excluded (test enumeration surface).
    pub fn live_node_ids(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .enumerate()
            .skip(1)
            .filter_map(|(id, n)| n.as_ref().map(|_| id))
            .collect()
    }

    pub fn stats(&self) -> &PrefixStats {
        &self.stats
    }

    /// RAM pool pages currently referenced by the tree.
    pub fn cached_pages(&self) -> usize {
        self.cached_pages
    }

    /// Pages currently spilled to the disk tier (still matchable).
    pub fn disk_pages(&self) -> usize {
        self.disk_pages
    }

    /// Extents dropped by true evictions since the last drain; the
    /// caller (whoever owns the tier store) frees them.
    pub fn take_dropped_extents(&mut self) -> Vec<DiskExtent> {
        std::mem::take(&mut self.dropped_extents)
    }

    /// Live nodes, excluding the root.
    pub fn num_nodes(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_some()).count() - 1
    }

    fn node(&self, id: NodeId) -> &Node {
        self.nodes[id].as_ref().expect("live node")
    }

    fn node_mut(&mut self, id: NodeId) -> &mut Node {
        self.nodes[id].as_mut().expect("live node")
    }

    fn alloc(&mut self, node: Node) -> NodeId {
        self.stats.inserted_nodes += 1;
        let id = match self.free_nodes.pop() {
            Some(id) => {
                self.nodes[id] = Some(node);
                id
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        };
        self.sync_index(id);
        id
    }

    /// Re-derive `id`'s membership in the eviction index from its
    /// current evictability. Call after any pin/children mutation.
    fn sync_index(&mut self, id: NodeId) {
        if id == 0 {
            return;
        }
        let key = (self.node(id).last_touch, id);
        if self.evictable(id) {
            self.evictable_index.insert(key);
        } else {
            self.evictable_index.remove(&key);
        }
    }

    /// LRU-refresh `id` to `clock`, re-keying its index entry. A clock
    /// at or behind the node's stamp is a no-op — under the shared set
    /// clock a tree must never move a node's recency backward.
    fn touch(&mut self, id: NodeId, clock: u64) {
        let old = self.node(id).last_touch;
        if old >= clock {
            return;
        }
        self.evictable_index.remove(&(old, id));
        self.node_mut(id).last_touch = clock;
        if self.evictable(id) {
            self.evictable_index.insert((clock, id));
        }
    }

    /// How many whole pages of `edge` match `tokens` (compared page by
    /// page from the start of both).
    fn matching_pages(&self, edge: &[u32], tokens: &[u32]) -> usize {
        let pt = self.cfg.page_tokens;
        let mut k = 0;
        while (k + 1) * pt <= edge.len()
            && (k + 1) * pt <= tokens.len()
            && edge[k * pt..(k + 1) * pt] == tokens[k * pt..(k + 1) * pt]
        {
            k += 1;
        }
        k
    }

    fn child_key(&self, edge: &[u32]) -> Vec<u32> {
        edge[..self.cfg.page_tokens].to_vec()
    }

    /// Longest cached prefix of `tokens`, page-granular. Touches every
    /// node on the matched path (LRU refresh) but takes no pins.
    pub fn match_prefix(&mut self, tokens: &[u32]) -> PrefixMatch {
        self.clock += 1;
        let clock = self.clock;
        self.match_prefix_at(tokens, clock)
    }

    /// Shared-clock variant: [`crate::prefix::PrefixCacheSet`] owns one
    /// monotonic clock across all trees so cross-codec LRU order
    /// (eviction *and* tier demotion) is globally coldest-first rather
    /// than per-tree approximate.
    pub fn match_prefix_at(&mut self, tokens: &[u32], clock: u64) -> PrefixMatch {
        let pt = self.cfg.page_tokens;
        self.clock = self.clock.max(clock);
        let mut cur: NodeId = 0;
        let mut walked = 0usize; // total matched tokens, disk tail included
        let mut matched = 0usize; // RAM-head tokens
        let mut pages: Vec<PageId> = Vec::new();
        let mut disk: Vec<NodeId> = Vec::new();
        loop {
            self.touch(cur, clock);
            if tokens.len() - walked < pt {
                break;
            }
            let key = tokens[walked..walked + pt].to_vec();
            let child = match self.node(cur).children.get(&key) {
                Some(&c) => c,
                None => break,
            };
            let k = {
                let c = self.node(child);
                self.matching_pages(&c.tokens, &tokens[walked..])
            };
            debug_assert!(k >= 1, "child key matched but first page did not");
            if k == 0 {
                break;
            }
            self.touch(child, clock);
            let c = self.node(child);
            let on_disk = matches!(c.pages.first(), Some(PageRef::Disk(_)));
            let edge_pages = c.pages.len();
            if on_disk || !disk.is_empty() {
                // Past the first spilled node everything is promotable-
                // only: the head handed to `register_with_prefix` must
                // be one contiguous run of RAM pages.
                if on_disk {
                    disk.push(child);
                }
            } else {
                for r in &c.pages[..k] {
                    match r {
                        PageRef::Ram(p) => pages.push(*p),
                        PageRef::Disk(_) => unreachable!("node pages are uniform"),
                    }
                }
                matched += k * pt;
            }
            walked += k * pt;
            cur = child;
            if k < edge_pages {
                break;
            }
        }
        PrefixMatch {
            pages,
            tokens: matched,
            node: if walked == 0 { None } else { Some(cur) },
            disk,
            disk_tokens: walked - matched,
        }
    }

    /// Pin a node for the lifetime of an active sequence: neither it nor
    /// (transitively) any ancestor can be evicted while pinned.
    pub fn pin(&mut self, node: NodeId) {
        self.node_mut(node).pins += 1;
        self.sync_index(node);
    }

    pub fn unpin(&mut self, node: NodeId) {
        let n = self.node_mut(node);
        debug_assert!(n.pins > 0, "unbalanced unpin");
        n.pins = n.pins.saturating_sub(1);
        self.sync_index(node);
    }

    /// Split `child` so its first `k` pages become a new intermediate node
    /// (the shared part); `child` keeps the diverging tail. Pool refcounts
    /// are untouched — pages just move between nodes. Returns the new
    /// intermediate node.
    fn split(&mut self, child: NodeId, k: usize) -> NodeId {
        let pt = self.cfg.page_tokens;
        let (parent, head_tokens, head_pages, tail_key, touch) = {
            let c = self.node(child);
            debug_assert!(k > 0 && k < c.pages.len());
            (
                c.parent,
                c.tokens[..k * pt].to_vec(),
                c.pages[..k].to_vec(),
                c.tokens[k * pt..k * pt + pt].to_vec(),
                c.last_touch,
            )
        };
        let old_key = self.child_key(&head_tokens);
        let mut children = BTreeMap::new();
        children.insert(tail_key, child);
        let mid = self.alloc(Node {
            tokens: head_tokens,
            pages: head_pages,
            children,
            parent,
            pins: 0,
            last_touch: touch,
        });
        {
            let c = self.node_mut(child);
            c.tokens.drain(..k * pt);
            c.pages.drain(..k);
            c.parent = mid;
        }
        self.node_mut(parent).children.insert(old_key, mid);
        mid
    }

    /// Insert the page-aligned prefix of `tokens` into the tree, taking
    /// page references from `src_seq`'s block table for any pages not
    /// already cached. Returns the deepest node on the inserted path
    /// (`None` when the prompt is shorter than one page or the sequence
    /// is unknown). The caller typically pins the returned node.
    pub fn insert(
        &mut self,
        tokens: &[u32],
        pool: &mut PagedPool,
        src_seq: u64,
    ) -> Option<NodeId> {
        self.clock += 1;
        let clock = self.clock;
        self.insert_at(tokens, pool, src_seq, clock)
    }

    /// Shared-clock variant of [`insert`](Self::insert) (see
    /// [`match_prefix_at`](Self::match_prefix_at)).
    pub fn insert_at(
        &mut self,
        tokens: &[u32],
        pool: &mut PagedPool,
        src_seq: u64,
        clock: u64,
    ) -> Option<NodeId> {
        let pt = self.cfg.page_tokens;
        let aligned = tokens.len() / pt * pt;
        if aligned == 0 {
            return None;
        }
        let src_pages: Vec<PageId> = pool.table(src_seq)?.pages.clone();
        if src_pages.len() < aligned / pt {
            return None; // table shorter than the prompt — shouldn't happen
        }
        self.clock = self.clock.max(clock);
        let mut cur: NodeId = 0;
        let mut off = 0usize;
        loop {
            self.touch(cur, clock);
            if off == aligned {
                return Some(cur);
            }
            let key = tokens[off..off + pt].to_vec();
            let child = match self.node(cur).children.get(&key) {
                Some(&c) => c,
                None => {
                    // New leaf owning the remaining pages of this prompt.
                    // The pages come from a live block table, so they are
                    // allocated and retain cannot fail.
                    let shared = &src_pages[off / pt..aligned / pt];
                    for &p in shared {
                        pool.retain_page(p).expect("page live via src table");
                    }
                    self.cached_pages += shared.len();
                    let pages = shared.iter().map(|&p| PageRef::Ram(p)).collect();
                    let own_pages = shared.len();
                    let leaf = self.alloc(Node {
                        tokens: tokens[off..aligned].to_vec(),
                        pages,
                        children: BTreeMap::new(),
                        parent: cur,
                        pins: 0,
                        last_touch: clock,
                    });
                    self.node_mut(cur).children.insert(key, leaf);
                    self.sync_index(cur); // cur is no longer a leaf
                    if self.publish {
                        // The new leaf covers the deepest `own_pages`
                        // depths of the inserted prefix; its ancestors
                        // advertised theirs when they were created.
                        self.dir_events.push(DirEvent {
                            retract: false,
                            tokens: tokens[..aligned].to_vec(),
                            pages: own_pages,
                        });
                    }
                    return Some(leaf);
                }
            };
            let k = {
                let c = self.node(child);
                self.matching_pages(&c.tokens, &tokens[off..aligned])
            };
            debug_assert!(k >= 1);
            self.touch(child, clock);
            if k == self.node(child).pages.len() {
                off += k * pt;
                cur = child;
                continue;
            }
            // Divergence inside the edge: split at the page boundary and
            // continue from the shared intermediate node.
            let mid = self.split(child, k);
            self.touch(mid, clock);
            off += k * pt;
            cur = mid;
        }
    }

    /// Whether a node can be evicted right now.
    fn evictable(&self, id: NodeId) -> bool {
        if id == 0 {
            return false;
        }
        let n = self.node(id);
        n.pins == 0 && n.children.is_empty()
    }

    /// Evict one LRU unpinned leaf passing `filter`, returning how many
    /// pool pages were actually freed (a page still referenced by an
    /// active sequence is released from the tree but stays allocated).
    /// `None` when no eligible victim exists.
    fn evict_one(&mut self, pool: &mut PagedPool, filter: VictimFilter) -> Option<usize> {
        // O(log n) victim pop from the eviction index, which holds
        // exactly the unpinned leaves ordered LRU-first (ties broken by
        // slab id, matching the old full-slab `min_by_key` scan). The
        // filtered walk skips ineligible victims in LRU order and is
        // O(1) in the common case.
        let victim = self
            .evictable_index
            .iter()
            .find(|&&(_, id)| {
                let n = self.node(id);
                match filter {
                    VictimFilter::Any => true,
                    VictimFilter::FreesRam => n
                        .pages
                        .iter()
                        .any(|r| matches!(r, PageRef::Ram(p) if pool.page_refcount(*p) == 1)),
                    VictimFilter::HoldsRam => {
                        matches!(n.pages.first(), Some(PageRef::Ram(_)))
                    }
                }
            })
            .map(|&(_, id)| id)?;
        if self.publish {
            // Retract exactly what this node's creation advertised: the
            // deepest `pages.len()` depths of its full token path.
            let ev = DirEvent {
                retract: true,
                tokens: self.token_path(victim),
                pages: self.node(victim).pages.len(),
            };
            self.dir_events.push(ev);
        }
        let node = self.nodes[victim].take().expect("live victim");
        self.evictable_index.remove(&(node.last_touch, victim));
        self.free_nodes.push(victim);
        let key = self.child_key(&node.tokens);
        self.node_mut(node.parent).children.remove(&key);
        self.sync_index(node.parent); // parent may have become a leaf
        let mut freed = 0;
        for r in node.pages {
            match r {
                PageRef::Ram(p) => {
                    self.cached_pages -= 1;
                    if pool.release_page(p).unwrap_or(false) {
                        freed += 1;
                    }
                }
                PageRef::Disk(ext) => {
                    // True eviction of a spilled page: hold the extent
                    // for the tier owner to free.
                    self.disk_pages -= 1;
                    self.dropped_extents.push(ext);
                }
            }
        }
        self.stats.evicted_nodes += 1;
        Some(freed)
    }

    /// Index/evictability consistency check (tests): the index must hold
    /// exactly the evictable nodes, keyed by their current last_touch.
    #[cfg(test)]
    fn check_eviction_index(&self) {
        let brute: BTreeSet<(u64, NodeId)> = self
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(id, n)| n.as_ref().map(|n| (id, n)))
            .filter(|&(id, _)| self.evictable(id))
            .map(|(id, n)| (n.last_touch, id))
            .collect();
        assert_eq!(self.evictable_index, brute, "eviction index out of sync");
    }

    /// Evict one LRU unpinned leaf regardless of residency or whether
    /// its pages free immediately (last-resort pressure path). Returns
    /// pages actually freed.
    pub fn evict_one_node(&mut self, pool: &mut PagedPool) -> Option<usize> {
        self.evict_one(pool, VictimFilter::Any)
    }

    /// Evict the LRU unpinned leaf that holds RAM pages (RAM-budget
    /// trims: disk nodes cost no pool bytes, so destroying them cannot
    /// help — see [`VictimFilter::HoldsRam`]). Returns pages freed.
    pub fn evict_one_ram_node(&mut self, pool: &mut PagedPool) -> Option<usize> {
        self.evict_one(pool, VictimFilter::HoldsRam)
    }

    /// Coldest evictable leaf (any residency) as `(last_touch, id)` —
    /// global-LRU victim selection across trees under the shared clock.
    pub fn coldest_evictable(&self) -> Option<(u64, NodeId)> {
        self.evictable_index.iter().next().copied()
    }

    /// Coldest leaf eligible for demotion: unpinned, childless, and all
    /// pages RAM-resident *and* cache-exclusive (refcount 1) — so
    /// releasing them after the spill frees real room. LRU order via
    /// the eviction index.
    pub fn coldest_demotable(&self, pool: &PagedPool) -> Option<(u64, NodeId)> {
        self.evictable_index
            .iter()
            .find(|&&(_, id)| {
                let n = self.node(id);
                !n.pages.is_empty()
                    && n.pages
                        .iter()
                        .all(|r| matches!(r, PageRef::Ram(p) if pool.page_refcount(*p) == 1))
            })
            .copied()
    }

    /// Pages (RAM or disk) referenced by node `id`; 0 for dead ids.
    pub fn node_page_count(&self, id: NodeId) -> usize {
        self.nodes
            .get(id)
            .and_then(|n| n.as_ref())
            .map_or(0, |n| n.pages.len())
    }

    /// Demote leaf `id` to the disk tier: write each page's bytes
    /// through `write`, release the RAM pages, and re-point the node at
    /// the returned extents. Eligibility is exactly
    /// [`coldest_demotable`](Self::coldest_demotable)'s — an unpinned,
    /// childless node whose pages are all cache-exclusive RAM. On a
    /// failed write (disk budget exhausted) the node keeps its RAM
    /// pages and the already-written extents land in the dropped list
    /// for the caller to free. Returns pages demoted.
    pub fn demote_node(
        &mut self,
        id: NodeId,
        pool: &mut PagedPool,
        write: &mut dyn FnMut(&[u8]) -> Option<DiskExtent>,
    ) -> Option<usize> {
        if !self.evictable(id) {
            return None;
        }
        let ram: Vec<PageId> = {
            let n = self.node(id);
            if n.pages.is_empty() {
                return None;
            }
            let mut ram = Vec::with_capacity(n.pages.len());
            for r in &n.pages {
                match r {
                    PageRef::Ram(p) if pool.page_refcount(*p) == 1 => ram.push(*p),
                    _ => return None,
                }
            }
            ram
        };
        let mut exts = Vec::with_capacity(ram.len());
        for &p in &ram {
            match write(pool.page_slice(p)) {
                Some(e) => exts.push(e),
                None => {
                    self.dropped_extents.extend(exts);
                    return None;
                }
            }
        }
        for &p in &ram {
            pool.release_page(p).expect("demotable page live");
        }
        let n_pages = exts.len();
        self.cached_pages -= n_pages;
        self.disk_pages += n_pages;
        self.node_mut(id).pages = exts.into_iter().map(PageRef::Disk).collect();
        Some(n_pages)
    }

    /// Promote node `id` back into RAM: allocate one pool page per
    /// extent, fill it through `read` (which must not free the extent),
    /// and re-point the node. Fails without side effects when the node
    /// is not fully on disk, the pool lacks room, or a read fails; on
    /// success returns the extents for the caller to free in its tier
    /// store. Works on inner disk nodes too (a demoted leaf that later
    /// gained children).
    pub fn promote_node(
        &mut self,
        id: NodeId,
        pool: &mut PagedPool,
        read: &mut dyn FnMut(DiskExtent, &mut [u8]) -> bool,
    ) -> Option<Vec<DiskExtent>> {
        let exts: Vec<DiskExtent> = {
            let n = self.nodes.get(id)?.as_ref()?;
            if n.pages.is_empty() {
                return None;
            }
            let mut exts = Vec::with_capacity(n.pages.len());
            for r in &n.pages {
                match r {
                    PageRef::Disk(e) => exts.push(*e),
                    PageRef::Ram(_) => return None,
                }
            }
            exts
        };
        if pool.free_pages() < exts.len() {
            return None;
        }
        let mut pages: Vec<PageId> = Vec::with_capacity(exts.len());
        for &e in &exts {
            let p = pool.alloc_page().expect("free pages pre-checked");
            if !read(e, pool.page_slice_mut(p)) {
                // Roll back: nothing was freed on disk, so the node's
                // extents stay valid.
                pool.release_page(p).ok();
                for &q in &pages {
                    pool.release_page(q).ok();
                }
                return None;
            }
            pages.push(p);
        }
        self.disk_pages -= exts.len();
        self.cached_pages += exts.len();
        self.node_mut(id).pages = pages.into_iter().map(PageRef::Ram).collect();
        Some(exts)
    }

    /// Evict LRU leaves until at least `pages_needed` pool pages have been
    /// freed or no eviction can free anything. Victims that would free no
    /// pages (all their pages still shared with active sequences) are
    /// left cached. Returns pages freed.
    pub fn evict_lru(&mut self, pool: &mut PagedPool, pages_needed: usize) -> usize {
        let mut freed = 0;
        while freed < pages_needed {
            match self.evict_one(pool, VictimFilter::FreesRam) {
                Some(f) => freed += f,
                None => break,
            }
        }
        freed
    }

    /// Pool pages eviction could free right now: pages held only by the
    /// cache (refcount 1) in nodes with no pinned node in their subtree.
    /// Exactly the set a full bottom-up eviction cascade reaches, since a
    /// pin protects itself and its ancestors but not siblings/descendants.
    pub fn freeable_pages(&self, pool: &PagedPool) -> usize {
        let mut protected = vec![false; self.nodes.len()];
        protected[0] = true; // root
        for (id, n) in self.nodes.iter().enumerate() {
            if n.as_ref().map(|n| n.pins > 0).unwrap_or(false) {
                let mut cur = id;
                while !protected[cur] {
                    protected[cur] = true;
                    cur = self.node(cur).parent;
                }
            }
        }
        self.nodes
            .iter()
            .enumerate()
            .filter_map(|(id, n)| n.as_ref().map(|n| (id, n)))
            .filter(|&(id, _)| !protected[id])
            .flat_map(|(_, n)| n.pages.iter())
            .filter(|r| matches!(r, PageRef::Ram(p) if pool.page_refcount(*p) == 1))
            .count()
    }

    /// Free at least `pages_needed` pool pages by evicting cache entries —
    /// or do nothing at all: when the cache cannot cover the shortfall,
    /// returns `false` without evicting, so a hopeless admission doesn't
    /// destroy reusable state on the way to failing anyway. Prefers
    /// victims whose pages free immediately, then falls back to cascaded
    /// eviction of unpinned subtrees.
    ///
    /// NOTE: the serving path goes through the multi-codec
    /// [`crate::prefix::PrefixCacheSet::make_room`], which applies this
    /// same policy (freeable precheck → `evict_lru` → `evict_one`
    /// fallback) globally across trees — keep the two in lockstep when
    /// changing the all-or-nothing semantics.
    pub fn make_room(&mut self, pool: &mut PagedPool, pages_needed: usize) -> bool {
        if pages_needed == 0 {
            return true;
        }
        if self.freeable_pages(pool) < pages_needed {
            return false;
        }
        let mut freed = self.evict_lru(pool, pages_needed);
        while freed < pages_needed {
            match self.evict_one(pool, VictimFilter::Any) {
                Some(f) => freed += f,
                None => break,
            }
        }
        freed >= pages_needed
    }

    /// Trim the cache back under its `max_pages` budget (memory
    /// pressure); pinned chains are skipped. Victims must hold RAM
    /// pages — the budget counts RAM, so true-evicting a spilled node
    /// would destroy tier-preserved state without freeing a byte.
    pub fn enforce_budget(&mut self, pool: &mut PagedPool) {
        while self.cached_pages > self.cfg.max_pages {
            if self.evict_one(pool, VictimFilter::HoldsRam).is_none() {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::paged::PagedConfig;

    const PT: usize = 4;

    fn pool(pages: usize) -> PagedPool {
        PagedPool::new(PagedConfig { page_tokens: PT, token_bytes: 2, num_pages: pages })
    }

    fn cache(max_pages: usize) -> RadixPrefixCache {
        RadixPrefixCache::new(PrefixConfig { page_tokens: PT, max_pages })
    }

    /// Register a sequence for `tokens` (+`extra` growth room) sharing the
    /// cache's longest matching prefix, then insert it — the scheduler's
    /// admit flow distilled.
    fn admit(
        c: &mut RadixPrefixCache,
        p: &mut PagedPool,
        seq: u64,
        tokens: &[u32],
        extra: usize,
    ) -> (usize, Option<NodeId>) {
        let m = c.match_prefix(tokens);
        p.register_with_prefix(seq, &m.pages, tokens.len() + extra).unwrap();
        let node = c.insert(tokens, p, seq);
        (m.tokens, node)
    }

    fn toks(spec: &[(u32, usize)]) -> Vec<u32> {
        let mut v = Vec::new();
        for &(val, n) in spec {
            v.extend(std::iter::repeat(val).take(n));
        }
        v
    }

    #[test]
    fn cold_miss_then_full_hit() {
        let (mut c, mut p) = (cache(64), pool(32));
        let prompt = toks(&[(7, 12)]); // 3 pages
        let (m0, node) = admit(&mut c, &mut p, 1, &prompt, 4);
        assert_eq!(m0, 0, "cold cache");
        assert!(node.is_some());
        assert_eq!(c.cached_pages(), 3);
        // Same prompt again: all 3 full pages hit.
        let m = c.match_prefix(&prompt);
        assert_eq!(m.tokens, 12);
        assert_eq!(m.pages.len(), 3);
        assert_eq!(m.pages, p.table(1).unwrap().pages[..3].to_vec());
    }

    #[test]
    fn partial_page_never_matches() {
        let (mut c, mut p) = (cache(64), pool(32));
        let prompt = toks(&[(7, 10)]); // 2 full pages + 2 tokens
        admit(&mut c, &mut p, 1, &prompt, 0);
        assert_eq!(c.cached_pages(), 2, "only full pages are cached");
        let m = c.match_prefix(&prompt);
        assert_eq!(m.tokens, 8);
    }

    #[test]
    fn divergence_splits_edge_and_shares_common_pages() {
        let (mut c, mut p) = (cache(64), pool(64));
        // 4 shared pages, then divergent tails of 2 pages each.
        let a = toks(&[(1, 16), (2, 8)]);
        let b = toks(&[(1, 16), (3, 8)]);
        admit(&mut c, &mut p, 1, &a, 0);
        assert_eq!(c.num_nodes(), 1, "single edge before divergence");
        let (mb, _) = admit(&mut c, &mut p, 2, &b, 0);
        assert_eq!(mb, 16, "common 4 pages matched");
        assert_eq!(c.num_nodes(), 3, "split: shared head + two tails");
        // The shared pages are the SAME pool pages in both tables (COW).
        let ta = p.table(1).unwrap().pages.clone();
        let tb = p.table(2).unwrap().pages.clone();
        assert_eq!(ta[..4], tb[..4]);
        assert_ne!(ta[4..], tb[4..]);
        // Cache now holds 4 shared + 2 + 2 divergent pages.
        assert_eq!(c.cached_pages(), 8);
        // Both tails still match end-to-end.
        assert_eq!(c.match_prefix(&a).tokens, 24);
        assert_eq!(c.match_prefix(&b).tokens, 24);
    }

    #[test]
    fn dir_events_mirror_node_lifetimes() {
        use crate::prefix::directory::PrefixDirectory;
        let (mut c, mut p) = (cache(64), pool(64));
        c.set_publish(true);
        let dir = PrefixDirectory::new(PT);
        // 4 shared pages, then divergent tails of 2 pages each.
        let a = toks(&[(1, 16), (2, 8)]);
        let b = toks(&[(1, 16), (3, 8)]);
        admit(&mut c, &mut p, 1, &a, 0);
        let ev = c.take_dir_events();
        assert_eq!(ev.len(), 1, "one new leaf");
        assert!(!ev[0].retract);
        assert_eq!((&ev[0].tokens, ev[0].pages), (&a, 6));
        dir.apply(0, "m", &ev[0]);
        assert_eq!(dir.lookup("m", &a), Some((24, vec![0])));
        // Divergence: the split moves pages between nodes (no event);
        // only b's fresh 2-page tail advertises.
        admit(&mut c, &mut p, 2, &b, 0);
        let ev = c.take_dir_events();
        assert_eq!(ev.len(), 1, "split itself publishes nothing");
        assert_eq!((&ev[0].tokens, ev[0].pages), (&b, 2));
        dir.apply(0, "m", &ev[0]);
        assert_eq!(dir.lookup("m", &b), Some((24, vec![0])));
        // Token paths reconstruct through the split.
        let mb = c.match_prefix(&b);
        assert_eq!(c.token_path(mb.node.unwrap()), b);
        // Evicting the whole tree retracts exactly what was advertised.
        while c.evict_one_node(&mut p).is_some() {}
        let ev = c.take_dir_events();
        assert_eq!(ev.len(), 3, "two tails + the shared head");
        assert!(ev.iter().all(|e| e.retract));
        for e in &ev {
            dir.apply(0, "m", e);
        }
        assert_eq!(dir.entries(), 0, "advertise/retract balance exactly");
        assert!(dir.lookup("m", &a).is_none());
    }

    #[test]
    fn diverge_within_first_page_makes_siblings() {
        let (mut c, mut p) = (cache(64), pool(64));
        let a = toks(&[(1, 3), (9, 5)]); // differs from b inside page 0
        let b = toks(&[(1, 3), (8, 5)]);
        admit(&mut c, &mut p, 1, &a, 0);
        let (mb, _) = admit(&mut c, &mut p, 2, &b, 0);
        assert_eq!(mb, 0, "no whole page in common");
        assert_eq!(c.num_nodes(), 2, "siblings under the root, no split");
        assert_eq!(c.match_prefix(&a).tokens, 8);
        assert_eq!(c.match_prefix(&b).tokens, 8);
    }

    #[test]
    fn shorter_prefix_insert_splits_and_matches() {
        let (mut c, mut p) = (cache(64), pool(64));
        let long = toks(&[(5, 16)]); // 4 pages
        let short = toks(&[(5, 8)]); // first 2 of them
        admit(&mut c, &mut p, 1, &long, 0);
        let (m, node) = admit(&mut c, &mut p, 2, &short, 0);
        assert_eq!(m, 8);
        assert!(node.is_some());
        assert_eq!(c.cached_pages(), 4, "no new pages: short is a prefix of long");
        assert_eq!(c.match_prefix(&long).tokens, 16);
    }

    #[test]
    fn pages_survive_source_sequence_release() {
        let (mut c, mut p) = (cache(64), pool(16));
        let prompt = toks(&[(4, 8)]);
        admit(&mut c, &mut p, 1, &prompt, 4);
        // Write recognizable bytes through seq 1, then release it.
        p.token_slot_mut(1, 0).unwrap().fill(0xEE);
        p.release(1).unwrap();
        // The cached pages are still resident; a new sequence sees them.
        let m = c.match_prefix(&prompt);
        assert_eq!(m.tokens, 8);
        p.register_with_prefix(2, &m.pages, 12).unwrap();
        assert_eq!(p.token_slot(2, 0).unwrap(), &[0xEE; 2]);
        p.release(2).unwrap();
    }

    #[test]
    fn lru_eviction_frees_cold_leaves_first() {
        let (mut c, mut p) = (cache(64), pool(64));
        let a = toks(&[(1, 8)]);
        let b = toks(&[(2, 8)]);
        admit(&mut c, &mut p, 1, &a, 0);
        admit(&mut c, &mut p, 2, &b, 0);
        p.release(1).unwrap();
        p.release(2).unwrap();
        // Touch `a` so `b` is the LRU entry.
        c.match_prefix(&a);
        let freed = c.evict_lru(&mut p, 2);
        assert_eq!(freed, 2);
        assert_eq!(c.match_prefix(&b).tokens, 0, "b evicted");
        assert_eq!(c.match_prefix(&a).tokens, 8, "a survived");
    }

    #[test]
    fn eviction_refuses_pinned_nodes() {
        let (mut c, mut p) = (cache(64), pool(64));
        let a = toks(&[(1, 16), (2, 8)]);
        admit(&mut c, &mut p, 1, &a, 0);
        let m = c.match_prefix(&a);
        let node = m.node.unwrap();
        c.pin(node);
        p.release(1).unwrap();
        // Pinned leaf (and transitively its ancestors) must survive.
        assert_eq!(c.evict_lru(&mut p, 100), 0);
        assert_eq!(c.match_prefix(&a).tokens, 24);
        // Unpin → evictable (leaf first, then the freed-up parent chain).
        c.unpin(node);
        assert!(c.evict_lru(&mut p, 100) >= 6);
        assert_eq!(c.match_prefix(&a).tokens, 0);
        assert_eq!(c.cached_pages(), 0);
    }

    #[test]
    fn pinned_inner_node_protects_ancestors_only() {
        let (mut c, mut p) = (cache(64), pool(64));
        let a = toks(&[(1, 16), (2, 8)]);
        let b = toks(&[(1, 16), (3, 8)]);
        admit(&mut c, &mut p, 1, &a, 0);
        let (_, nb) = admit(&mut c, &mut p, 2, &b, 0);
        c.pin(nb.unwrap());
        p.release(1).unwrap();
        p.release(2).unwrap();
        // Evict everything possible: a's tail goes, b's chain stays.
        c.evict_lru(&mut p, 100);
        assert_eq!(c.match_prefix(&b).tokens, 24);
        assert_eq!(c.match_prefix(&a).tokens, 16, "shared head survives via b");
    }

    #[test]
    fn make_room_is_all_or_nothing() {
        let (mut c, mut p) = (cache(64), pool(16));
        // One cold entry (2 freeable pages) + one pinned entry.
        let cold = toks(&[(1, 8)]);
        let hot = toks(&[(2, 8)]);
        admit(&mut c, &mut p, 1, &cold, 0);
        let (_, hot_node) = admit(&mut c, &mut p, 2, &hot, 0);
        c.pin(hot_node.unwrap());
        p.release(1).unwrap();
        p.release(2).unwrap();
        assert_eq!(c.freeable_pages(&p), 2, "only the cold entry is freeable");
        // Asking for more than the cache can ever free: nothing evicted.
        assert!(!c.make_room(&mut p, 3));
        assert_eq!(c.match_prefix(&cold).tokens, 8, "cold entry untouched");
        // Asking for what it can free succeeds and frees exactly enough.
        assert!(c.make_room(&mut p, 2));
        assert_eq!(c.match_prefix(&cold).tokens, 0);
        assert_eq!(c.match_prefix(&hot).tokens, 8, "pinned entry survives");
    }

    #[test]
    fn budget_enforcement_trims_lru() {
        let (mut c, mut p) = (cache(4), pool(64));
        for (i, t) in [1u32, 2, 3].iter().enumerate() {
            let prompt = toks(&[(*t, 8)]); // 2 pages each
            admit(&mut c, &mut p, i as u64 + 1, &prompt, 0);
            p.release(i as u64 + 1).unwrap();
            c.enforce_budget(&mut p);
        }
        assert!(c.cached_pages() <= 4, "budget enforced: {}", c.cached_pages());
        // The most recent prompt is still cached.
        assert_eq!(c.match_prefix(&toks(&[(3, 8)])).tokens, 8);
    }

    #[test]
    fn eviction_index_stays_consistent_under_churn() {
        // Property check: after every mutating operation the O(log n)
        // eviction index must equal the brute-force evictable scan it
        // replaced.
        let (mut c, mut p) = (cache(64), pool(64));
        let mut seq = 0u64;
        for round in 0u32..30 {
            let prompt = toks(&[(round % 7, 4 + 4 * (round as usize % 3)), (round, 4)]);
            seq += 1;
            let m = c.match_prefix(&prompt);
            c.check_eviction_index();
            if p.register_with_prefix(seq, &m.pages, prompt.len()).is_ok() {
                let node = c.insert(&prompt, &mut p, seq);
                c.check_eviction_index();
                if let Some(n) = node {
                    c.pin(n);
                    c.check_eviction_index();
                    c.unpin(n);
                    c.check_eviction_index();
                }
                p.release(seq).unwrap();
            }
            if round % 5 == 4 {
                c.evict_lru(&mut p, 3);
                c.check_eviction_index();
            }
        }
        c.evict_lru(&mut p, 1000);
        c.check_eviction_index();
        assert_eq!(c.cached_pages(), 0, "everything unpinned was evictable");
    }

    /// An in-memory stand-in for the disk tier's segment file: extents
    /// index into a Vec of page-byte blobs.
    struct MemTier {
        blobs: Vec<Vec<u8>>,
    }

    impl MemTier {
        fn new() -> Self {
            Self { blobs: Vec::new() }
        }
        fn write(&mut self, bytes: &[u8]) -> Option<DiskExtent> {
            self.blobs.push(bytes.to_vec());
            Some(DiskExtent { offset: (self.blobs.len() - 1) as u64, len: bytes.len() as u32 })
        }
        fn read(&self, ext: DiskExtent, buf: &mut [u8]) -> bool {
            buf.copy_from_slice(&self.blobs[ext.offset as usize]);
            true
        }
    }

    #[test]
    fn demote_then_promote_restores_bytes_and_match() {
        let (mut c, mut p) = (cache(64), pool(16));
        let prompt = toks(&[(3, 8)]); // 2 pages
        let (_, node) = admit(&mut c, &mut p, 1, &prompt, 0);
        let node = node.unwrap();
        for t in 0..8 {
            p.token_slot_mut(1, t).unwrap().fill(0xA0 | t as u8);
        }
        let snapshot: Vec<Vec<u8>> = c
            .match_prefix(&prompt)
            .pages
            .iter()
            .map(|&pg| p.page_slice(pg).to_vec())
            .collect();
        p.release(1).unwrap();
        let mut tier = MemTier::new();
        assert_eq!(
            c.demote_node(node, &mut p, &mut |b| tier.write(b)),
            Some(2),
            "both pages spilled"
        );
        c.check_eviction_index();
        assert_eq!(p.used_pages(), 0, "RAM freed by demotion");
        assert_eq!((c.cached_pages(), c.disk_pages()), (0, 2));
        // The entry still matches, but as promotable-only tokens.
        let m = c.match_prefix(&prompt);
        assert_eq!(m.tokens, 0);
        assert_eq!(m.disk, vec![node]);
        assert_eq!(m.disk_tokens, 8);
        assert_eq!(m.node, Some(node));
        // Promote: fresh pages, byte-identical content.
        let exts = c
            .promote_node(node, &mut p, &mut |e, buf| tier.read(e, buf))
            .expect("promoted");
        assert_eq!(exts.len(), 2);
        assert_eq!((c.cached_pages(), c.disk_pages()), (2, 0));
        let m = c.match_prefix(&prompt);
        assert_eq!(m.tokens, 8);
        assert!(m.disk.is_empty());
        for (i, &pg) in m.pages.iter().enumerate() {
            assert_eq!(p.page_slice(pg), &snapshot[i][..], "page {i} byte-identical");
        }
        c.check_eviction_index();
    }

    #[test]
    fn demotion_refuses_pinned_shared_and_disk_nodes() {
        let (mut c, mut p) = (cache(64), pool(16));
        let prompt = toks(&[(4, 8)]);
        let (_, node) = admit(&mut c, &mut p, 1, &prompt, 0);
        let node = node.unwrap();
        let mut tier = MemTier::new();
        // Pages still shared with the active sequence: not demotable.
        assert!(c.coldest_demotable(&p).is_none());
        assert!(c.demote_node(node, &mut p, &mut |b| tier.write(b)).is_none());
        p.release(1).unwrap();
        // Pinned: not demotable.
        c.pin(node);
        assert!(c.demote_node(node, &mut p, &mut |b| tier.write(b)).is_none());
        c.unpin(node);
        assert_eq!(c.coldest_demotable(&p), Some((c.node(node).last_touch, node)));
        assert_eq!(c.demote_node(node, &mut p, &mut |b| tier.write(b)), Some(2));
        // Already on disk: demoting again is a no-op failure.
        assert!(c.demote_node(node, &mut p, &mut |b| tier.write(b)).is_none());
        assert!(c.coldest_demotable(&p).is_none(), "disk nodes are not demotable");
    }

    #[test]
    fn failed_spill_keeps_ram_pages_and_drops_partial_extents() {
        let (mut c, mut p) = (cache(64), pool(16));
        let prompt = toks(&[(5, 12)]); // 3 pages
        let (_, node) = admit(&mut c, &mut p, 1, &prompt, 0);
        let node = node.unwrap();
        p.release(1).unwrap();
        // Budget admits one page, then fails: all-or-nothing demotion.
        let mut wrote = 0;
        let res = c.demote_node(node, &mut p, &mut |b| {
            wrote += 1;
            if wrote == 1 {
                Some(DiskExtent { offset: 0, len: b.len() as u32 })
            } else {
                None
            }
        });
        assert!(res.is_none());
        assert_eq!(p.used_pages(), 3, "RAM pages untouched");
        assert_eq!(c.match_prefix(&prompt).tokens, 12, "entry still RAM-served");
        assert_eq!(c.take_dropped_extents().len(), 1, "partial extent surrendered");
    }

    #[test]
    fn evicting_a_disk_node_surrenders_its_extents() {
        let (mut c, mut p) = (cache(64), pool(16));
        let prompt = toks(&[(6, 8)]);
        let (_, node) = admit(&mut c, &mut p, 1, &prompt, 0);
        p.release(1).unwrap();
        let mut tier = MemTier::new();
        c.demote_node(node.unwrap(), &mut p, &mut |b| tier.write(b)).unwrap();
        // Budget-pressure eviction true-drops the spilled entry.
        assert_eq!(c.evict_one_node(&mut p), Some(0), "no RAM pages to free");
        assert_eq!(c.disk_pages(), 0);
        assert_eq!(c.take_dropped_extents().len(), 2);
        assert_eq!(c.match_prefix(&prompt).tokens, 0);
        assert_eq!(c.match_prefix(&prompt).disk_tokens, 0);
        c.check_eviction_index();
    }

    #[test]
    fn budget_trims_never_true_evict_disk_nodes() {
        // The RAM budget counts RAM pages, so its eviction pass must
        // skip spilled nodes: destroying them frees nothing and loses
        // exactly the state the tier preserved.
        let (mut c, mut p) = (cache(2), pool(16)); // budget: 2 RAM pages
        let cold = toks(&[(1, 8)]); // 2 pages, spilled below
        let warm = toks(&[(2, 16)]); // 4 RAM pages, over budget
        let (_, cold_node) = admit(&mut c, &mut p, 1, &cold, 0);
        p.release(1).unwrap();
        let mut tier = MemTier::new();
        c.demote_node(cold_node.unwrap(), &mut p, &mut |b| tier.write(b)).unwrap();
        admit(&mut c, &mut p, 2, &warm, 0);
        p.release(2).unwrap();
        c.enforce_budget(&mut p);
        assert!(c.cached_pages() <= 2, "budget enforced on RAM pages");
        assert_eq!(c.disk_pages(), 2, "spilled entry untouched by the trim");
        assert_eq!(c.match_prefix(&cold).disk_tokens, 8, "still promotable");
        assert!(c.take_dropped_extents().is_empty(), "no true evictions");
        // Once every RAM victim is gone the trim stops rather than
        // falling through to disk nodes.
        c.enforce_budget(&mut p);
        assert_eq!(c.disk_pages(), 2);
    }

    #[test]
    fn promote_requires_room_and_fails_cleanly() {
        let (mut c, mut p) = (cache(64), pool(2));
        let prompt = toks(&[(7, 8)]); // exactly the whole pool
        let (_, node) = admit(&mut c, &mut p, 1, &prompt, 0);
        let node = node.unwrap();
        p.release(1).unwrap();
        let mut tier = MemTier::new();
        c.demote_node(node, &mut p, &mut |b| tier.write(b)).unwrap();
        // Fill the pool with someone else's pages: no room to promote.
        p.register(2, 8).unwrap();
        assert!(c.promote_node(node, &mut p, &mut |e, buf| tier.read(e, buf)).is_none());
        assert_eq!(c.disk_pages(), 2, "extents untouched by the failed attempt");
        p.release(2).unwrap();
        // A failing read rolls back the allocated pages.
        assert!(c.promote_node(node, &mut p, &mut |_, _| false).is_none());
        assert_eq!(p.used_pages(), 0);
        // And a clean retry still works afterwards.
        assert!(c.promote_node(node, &mut p, &mut |e, buf| tier.read(e, buf)).is_some());
        assert_eq!(c.match_prefix(&prompt).tokens, 8);
    }

    #[test]
    fn match_truncates_ram_head_at_first_disk_node() {
        let (mut c, mut p) = (cache(64), pool(64));
        // Shared 2-page head, divergent 2-page tails → head + 2 leaves.
        let a = toks(&[(1, 8), (2, 8)]);
        let b = toks(&[(1, 8), (3, 8)]);
        admit(&mut c, &mut p, 1, &a, 0);
        let (_, nb) = admit(&mut c, &mut p, 2, &b, 0);
        p.release(1).unwrap();
        p.release(2).unwrap();
        let mut tier = MemTier::new();
        // Demote only b's tail leaf: the RAM head still serves 8 tokens.
        c.demote_node(nb.unwrap(), &mut p, &mut |bts| tier.write(bts)).unwrap();
        let m = c.match_prefix(&b);
        assert_eq!(m.tokens, 8, "RAM head");
        assert_eq!(m.pages.len(), 2);
        assert_eq!(m.disk_tokens, 8, "tail promotable");
        assert_eq!(m.disk, vec![nb.unwrap()]);
        // a's path is untouched.
        assert_eq!(c.match_prefix(&a).tokens, 16);
    }

    #[test]
    fn make_room_eviction_skips_nodes_shared_with_active_seqs() {
        let (mut c, mut p) = (cache(64), pool(16));
        let prompt = toks(&[(6, 8)]);
        admit(&mut c, &mut p, 1, &prompt, 0);
        // Seq 1 is still active (its table shares the cached pages), so
        // evicting this node would free nothing — it must be left cached
        // rather than destroyed for no reclaimed room.
        let freed = c.evict_lru(&mut p, 100);
        assert_eq!(freed, 0, "nothing reclaimable while the sequence runs");
        assert_eq!(c.match_prefix(&prompt).tokens, 8, "entry survives");
        assert_eq!(p.used_pages(), 2);
        // Once the sequence retires, the same eviction reclaims the pages.
        p.release(1).unwrap();
        assert_eq!(c.evict_lru(&mut p, 100), 2);
        assert_eq!(p.used_pages(), 0);
        // Budget enforcement, by contrast, may drop still-shared nodes.
        admit(&mut c, &mut p, 2, &prompt, 0);
        let mut tight = RadixPrefixCache::new(PrefixConfig { page_tokens: PT, max_pages: 0 });
        let m = tight.insert(&prompt, &mut p, 2);
        assert!(m.is_some());
        tight.enforce_budget(&mut p);
        assert_eq!(tight.cached_pages(), 0, "budget eviction drops shared nodes");
        p.release(2).unwrap();
    }
}
