//! The common interface all KV-cache compression methods implement
//! (PolarQuant and every baseline in Table 1 / Fig. 3).
//!
//! A method compresses a *prefill block* of per-head keys/values (given an
//! observation window of recent queries, which score-based eviction
//! methods need), producing a [`CompressedKv`] the attention path queries
//! directly:
//!
//! * `key_scores(q)` computes K̂·q — **dequantizing on the fly**, so each
//!   method pays its real decode-time cost (this is what Table 2 measures);
//! * `value_combine(w)` computes Σᵢ wᵢ·V̂ᵢ the same way;
//! * `append` adds generation-tail tokens (kept full precision by every
//!   method, per paper §5.3).
//!
//! Memory accounting (`memory_bytes`) includes quantization constants
//! (zero points/scales/norms) — the overhead PolarQuant's normalization-
//! free design avoids, which is the headline claim.

/// A prefill block of per-head KV embeddings (row-major n × d).
#[derive(Clone, Debug)]
pub struct KvBlock {
    pub keys: Vec<f32>,
    pub values: Vec<f32>,
    pub n: usize,
    pub d: usize,
}

impl KvBlock {
    pub fn new(keys: Vec<f32>, values: Vec<f32>, n: usize, d: usize) -> Self {
        assert_eq!(keys.len(), n * d);
        assert_eq!(values.len(), n * d);
        Self { keys, values, n, d }
    }

    pub fn key(&self, i: usize) -> &[f32] {
        &self.keys[i * self.d..(i + 1) * self.d]
    }

    pub fn value(&self, i: usize) -> &[f32] {
        &self.values[i * self.d..(i + 1) * self.d]
    }

    /// fp16 baseline footprint of this block (the denominator of every
    /// compression ratio in the paper).
    pub fn fp16_bytes(&self) -> usize {
        2 * 2 * self.n * self.d
    }
}

/// A compressed per-head KV cache segment plus its full-precision tail.
pub trait CompressedKv: Send {
    /// Number of retained prefill tokens + appended tail tokens.
    fn n_tokens(&self) -> usize;

    /// Original token positions of every retained/append token, in cache
    /// order (needed for causal masking and NIAH scoring).
    fn positions(&self) -> Vec<u32>;

    /// Total bytes of storage, including quantization constants.
    fn memory_bytes(&self) -> usize;

    /// scores[i] = ⟨K̂ᵢ, q⟩ for every cached token i (dequantize-on-read).
    fn key_scores(&self, q: &[f32], scores: &mut Vec<f32>);

    /// out += Σᵢ weights[i]·V̂ᵢ (dequantize-on-read). `out` pre-zeroed by
    /// caller; len d.
    fn value_combine(&self, weights: &[f32], out: &mut [f32]);

    /// Append a generation-step (k, v) in full precision (paper §5.3).
    fn append(&mut self, position: u32, k: &[f32], v: &[f32]);

    /// Materialize dequantized keys (n × d) — debugging/tests only.
    fn dequant_keys(&self) -> Vec<f32> {
        let d = self.dim();
        let n = self.n_tokens();
        let mut out = vec![0.0f32; n * d];
        // Default: reconstruct via basis probes (exact since key_scores is
        // linear in q). O(d) probes — fine for tests.
        let mut scores = Vec::new();
        let mut e = vec![0.0f32; d];
        for j in 0..d {
            e.fill(0.0);
            e[j] = 1.0;
            self.key_scores(&e, &mut scores);
            for i in 0..n {
                out[i * d + j] = scores[i];
            }
        }
        out
    }

    fn dim(&self) -> usize;
}

/// A compression method: turns prefill blocks into [`CompressedKv`] stores.
pub trait KvCompressor: Send + Sync {
    fn name(&self) -> String;

    /// Compress one head's prefill block. `obs_queries` holds the last W
    /// prefill queries (row-major w × d) — used by score-based eviction
    /// (SnapKV family); quantization methods ignore it.
    fn compress(&self, block: &KvBlock, obs_queries: &[f32]) -> Box<dyn CompressedKv>;

    /// Nominal compression ratio this instance is configured for
    /// (memory / fp16 memory); used to line methods up at ratio 0.25.
    fn target_ratio(&self) -> f64;
}

/// Shared scorer for the SnapKV family: mean attention mass each prefill
/// token receives from the observation-window queries, max-pooled over a
/// small neighborhood (SnapKV §3: pooling keeps contiguous spans).
pub fn observation_scores(block: &KvBlock, obs_queries: &[f32], pool: usize) -> Vec<f64> {
    let d = block.d;
    let w = obs_queries.len() / d.max(1);
    let n = block.n;
    let mut acc = vec![0.0f64; n];
    if w == 0 || n == 0 {
        return acc;
    }
    let scale = 1.0 / (d as f32).sqrt();
    let mut logits = vec![0.0f32; n];
    for qi in 0..w {
        let q = &obs_queries[qi * d..(qi + 1) * d];
        for i in 0..n {
            logits[i] = crate::math::linalg::dot(block.key(i), q) * scale;
        }
        crate::math::linalg::softmax(&mut logits);
        for i in 0..n {
            acc[i] += logits[i] as f64;
        }
    }
    for a in acc.iter_mut() {
        *a /= w as f64;
    }
    // Max-pool over a neighborhood so selected tokens form spans.
    if pool > 1 {
        let half = pool / 2;
        let orig = acc.clone();
        for i in 0..n {
            let lo = i.saturating_sub(half);
            let hi = (i + half + 1).min(n);
            acc[i] = orig[lo..hi].iter().cloned().fold(f64::MIN, f64::max);
        }
    }
    acc
}

/// Pick the indices of the `budget` highest-scoring tokens, always forcing
/// the `recent` most-recent tokens in (every eviction method keeps the
/// local window). Returns sorted unique indices.
pub fn select_topk_with_recent(scores: &[f64], budget: usize, recent: usize) -> Vec<usize> {
    let n = scores.len();
    let budget = budget.min(n);
    let recent_start = n.saturating_sub(recent.min(budget));
    let mut chosen: Vec<usize> = (recent_start..n).collect();
    let remaining = budget - chosen.len();
    if remaining > 0 {
        let mut idx: Vec<usize> = (0..recent_start).collect();
        idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
        chosen.extend(idx.into_iter().take(remaining));
    }
    chosen.sort_unstable();
    chosen.dedup();
    chosen
}

/// An uncompressed full-precision tail segment (shared by every method for
/// generation-stage appends).
#[derive(Clone, Debug, Default)]
pub struct FpTail {
    pub d: usize,
    pub positions: Vec<u32>,
    /// f16 bit patterns, row-major.
    pub keys: Vec<u16>,
    pub values: Vec<u16>,
}

impl FpTail {
    pub fn new(d: usize) -> Self {
        Self { d, positions: Vec::new(), keys: Vec::new(), values: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.positions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.positions.is_empty()
    }

    pub fn append(&mut self, position: u32, k: &[f32], v: &[f32]) {
        assert_eq!(k.len(), self.d);
        assert_eq!(v.len(), self.d);
        self.positions.push(position);
        self.keys.extend(crate::quant::fp16::encode_f16(k));
        self.values.extend(crate::quant::fp16::encode_f16(v));
    }

    pub fn memory_bytes(&self) -> usize {
        self.positions.len() * 4 + (self.keys.len() + self.values.len()) * 2
    }

    // analyze: allow(hot_path_alloc, "legacy per-sequence heap path: pushes into the caller's amortized scores buffer; the pool substrate is the serving default")
    pub fn key_scores_into(&self, q: &[f32], scores: &mut Vec<f32>) {
        let d = self.d;
        for i in 0..self.len() {
            let row = &self.keys[i * d..(i + 1) * d];
            let mut s = 0.0f32;
            for (j, &h) in row.iter().enumerate() {
                s += crate::quant::fp16::f16_bits_to_f32(h) * q[j];
            }
            scores.push(s);
        }
    }

    pub fn value_combine(&self, weights: &[f32], out: &mut [f32]) {
        let d = self.d;
        for (i, &w) in weights.iter().enumerate() {
            if w == 0.0 {
                continue;
            }
            let row = &self.values[i * d..(i + 1) * d];
            for j in 0..d {
                out[j] += w * crate::quant::fp16::f16_bits_to_f32(row[j]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng};

    fn block(n: usize, d: usize, seed: u64) -> KvBlock {
        let mut rng = Pcg64::new(seed);
        let mut k = vec![0.0f32; n * d];
        let mut v = vec![0.0f32; n * d];
        rng.fill_gaussian(&mut k);
        rng.fill_gaussian(&mut v);
        KvBlock::new(k, v, n, d)
    }

    #[test]
    fn fp16_bytes_accounting() {
        let b = block(10, 8, 1);
        assert_eq!(b.fp16_bytes(), 2 * 2 * 80);
    }

    #[test]
    fn observation_scores_highlight_attended_token() {
        // Make token 5's key equal to the query → it dominates softmax.
        let d = 16;
        let mut b = block(32, d, 2);
        let mut rng = Pcg64::new(3);
        let mut q = vec![0.0f32; d];
        rng.fill_gaussian(&mut q);
        for j in 0..d {
            b.keys[5 * d + j] = q[j] * 4.0;
        }
        let scores = observation_scores(&b, &q, 1);
        let best = scores
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(best, 5);
    }

    #[test]
    fn pooling_spreads_scores() {
        let d = 8;
        let mut b = block(16, d, 4);
        let mut q = vec![0.0f32; d];
        q[0] = 1.0;
        for j in 0..d {
            b.keys[7 * d + j] = q[j] * 10.0;
        }
        let pooled = observation_scores(&b, &q, 5);
        // Neighbors of 7 inherit its pooled score.
        assert!(pooled[6] >= pooled[2]);
        assert!(pooled[8] >= pooled[2]);
    }

    #[test]
    fn topk_selection_keeps_recent_and_top() {
        let scores = vec![0.9, 0.1, 0.8, 0.2, 0.05, 0.01];
        let sel = select_topk_with_recent(&scores, 4, 2);
        // Last 2 forced in (4, 5); top-2 of the rest are 0 and 2.
        assert_eq!(sel, vec![0, 2, 4, 5]);
    }

    #[test]
    fn topk_budget_clamped() {
        let scores = vec![1.0, 2.0];
        let sel = select_topk_with_recent(&scores, 10, 5);
        assert_eq!(sel, vec![0, 1]);
    }

    #[test]
    fn fp_tail_roundtrip_scores() {
        let d = 8;
        let mut tail = FpTail::new(d);
        let mut rng = Pcg64::new(5);
        let mut k = vec![0.0f32; d];
        let mut v = vec![0.0f32; d];
        rng.fill_gaussian(&mut k);
        rng.fill_gaussian(&mut v);
        tail.append(100, &k, &v);
        let q = vec![1.0f32; d];
        let mut scores = Vec::new();
        tail.key_scores_into(&q, &mut scores);
        let want: f32 = k.iter().sum();
        assert!((scores[0] - want).abs() < 0.02);
        let mut out = vec![0.0f32; d];
        tail.value_combine(&[2.0], &mut out);
        for j in 0..d {
            assert!((out[j] - 2.0 * v[j]).abs() < 0.02);
        }
    }
}
