//! Token-eviction baselines (paper §5: SnapKV, PyramidKV, StreamingLLM,
//! HeadKV). All four keep a *subset* of prefill tokens in fp16 and drop
//! the rest; they differ only in how the subset is chosen:
//!
//! * **SnapKV** [24]: score prefill tokens by the attention mass they get
//!   from an observation window of the last queries (max-pooled into
//!   spans), keep top-budget plus the recent window.
//! * **PyramidKV** [8]: SnapKV scoring with a per-layer budget that decays
//!   up the stack ("pyramidal information funneling") — lower layers keep
//!   more, upper layers fewer, same average.
//! * **StreamingLLM** [38]: no scores — keep the first `sinks` tokens
//!   (attention sinks) plus the most recent window.
//! * **HeadKV** [13]: SnapKV scoring with per-head budgets allocated
//!   proportionally to a head-importance (retrieval-reasoning) score, so
//!   important heads keep more under the same total.
//!
//! Shared store: [`EvictedKv`], fp16 rows for retained tokens.

use crate::quant::compressor::{
    observation_scores, select_topk_with_recent, CompressedKv, FpTail, KvBlock, KvCompressor,
};
use crate::quant::fp16::{encode_f16, f16_bits_to_f32};

/// Which eviction policy to apply.
#[derive(Clone, Debug)]
pub enum EvictionPolicy {
    SnapKv {
        /// Observation-window pooling width (SnapKV paper uses 7).
        pool: usize,
    },
    PyramidKv {
        pool: usize,
        /// This head's layer and the total layer count (budget decays
        /// linearly from 2× at layer 0 to ~0.25× at the top, normalized to
        /// preserve the average).
        layer: usize,
        num_layers: usize,
    },
    StreamingLlm {
        /// Number of initial attention-sink tokens to pin.
        sinks: usize,
    },
    HeadKv {
        pool: usize,
        /// Relative importance of this head in [0, 1]; budgets scale as
        /// 0.5 + 1.5·importance (normalized so the fleet average is ~1×).
        importance: f64,
    },
}

/// Eviction compressor: policy + target compression ratio (the fraction of
/// prefill tokens retained; paper Fig. 3 sets 0.25 for all methods).
#[derive(Clone, Debug)]
pub struct EvictionCompressor {
    pub policy: EvictionPolicy,
    pub ratio: f64,
    /// Recent-window fraction of the budget always retained (SnapKV keeps
    /// the observation window verbatim).
    pub recent_frac: f64,
}

impl EvictionCompressor {
    pub fn snapkv(ratio: f64) -> Self {
        Self { policy: EvictionPolicy::SnapKv { pool: 7 }, ratio, recent_frac: 0.25 }
    }

    pub fn pyramidkv(ratio: f64, layer: usize, num_layers: usize) -> Self {
        Self {
            policy: EvictionPolicy::PyramidKv { pool: 7, layer, num_layers },
            ratio,
            recent_frac: 0.25,
        }
    }

    pub fn streamingllm(ratio: f64) -> Self {
        Self { policy: EvictionPolicy::StreamingLlm { sinks: 4 }, ratio, recent_frac: 1.0 }
    }

    pub fn headkv(ratio: f64, importance: f64) -> Self {
        Self {
            policy: EvictionPolicy::HeadKv { pool: 7, importance },
            ratio,
            recent_frac: 0.25,
        }
    }

    fn budget(&self, n: usize) -> usize {
        let base = (self.ratio * n as f64).round();
        let scaled = match &self.policy {
            EvictionPolicy::PyramidKv { layer, num_layers, .. } => {
                // Linear decay 1.75× → 0.25× across layers, mean 1.0.
                let nl = (*num_layers).max(1) as f64;
                let t = *layer as f64 / (nl - 1.0).max(1.0);
                base * (1.75 - 1.5 * t)
            }
            EvictionPolicy::HeadKv { importance, .. } => base * (0.5 + 1.5 * importance),
            _ => base,
        };
        (scaled as usize).clamp(1, n)
    }
}

impl KvCompressor for EvictionCompressor {
    fn name(&self) -> String {
        match &self.policy {
            EvictionPolicy::SnapKv { .. } => "snapkv".into(),
            EvictionPolicy::PyramidKv { .. } => "pyramidkv".into(),
            EvictionPolicy::StreamingLlm { .. } => "streamingllm".into(),
            EvictionPolicy::HeadKv { .. } => "headkv".into(),
        }
    }

    fn compress(&self, block: &KvBlock, obs_queries: &[f32]) -> Box<dyn CompressedKv> {
        let n = block.n;
        let budget = self.budget(n);
        let keep: Vec<usize> = match &self.policy {
            EvictionPolicy::StreamingLlm { sinks } => {
                // Sinks + most recent (budget − sinks).
                let sinks = (*sinks).min(budget);
                let recent = budget - sinks;
                let mut keep: Vec<usize> = (0..sinks).collect();
                keep.extend(n.saturating_sub(recent)..n);
                keep.dedup();
                keep
            }
            EvictionPolicy::SnapKv { pool }
            | EvictionPolicy::PyramidKv { pool, .. }
            | EvictionPolicy::HeadKv { pool, .. } => {
                let scores = observation_scores(block, obs_queries, *pool);
                let recent = ((budget as f64) * self.recent_frac) as usize;
                select_topk_with_recent(&scores, budget, recent)
            }
        };

        let d = block.d;
        let mut keys = Vec::with_capacity(keep.len() * d);
        let mut values = Vec::with_capacity(keep.len() * d);
        for &i in &keep {
            keys.extend(encode_f16(block.key(i)));
            values.extend(encode_f16(block.value(i)));
        }
        Box::new(EvictedKv {
            d,
            positions: keep.iter().map(|&i| i as u32).collect(),
            keys,
            values,
            tail: FpTail::new(d),
        })
    }

    fn target_ratio(&self) -> f64 {
        self.ratio
    }
}

/// Retained-subset fp16 store.
pub struct EvictedKv {
    d: usize,
    positions: Vec<u32>,
    keys: Vec<u16>,
    values: Vec<u16>,
    tail: FpTail,
}

impl CompressedKv for EvictedKv {
    fn n_tokens(&self) -> usize {
        self.positions.len() + self.tail.len()
    }

    fn positions(&self) -> Vec<u32> {
        let mut p = self.positions.clone();
        p.extend_from_slice(&self.tail.positions);
        p
    }

    fn memory_bytes(&self) -> usize {
        // f16 rows + 4-byte position indices (eviction must store which
        // tokens survive) + tail.
        (self.keys.len() + self.values.len()) * 2
            + self.positions.len() * 4
            + self.tail.memory_bytes()
    }

    // analyze: allow(hot_path_alloc, "legacy per-sequence heap path: pushes into the caller's amortized scores buffer; the pool substrate is the serving default")
    fn key_scores(&self, q: &[f32], scores: &mut Vec<f32>) {
        scores.clear();
        let d = self.d;
        for i in 0..self.positions.len() {
            let row = &self.keys[i * d..(i + 1) * d];
            let mut s = 0.0f32;
            for j in 0..d {
                s += f16_bits_to_f32(row[j]) * q[j];
            }
            scores.push(s);
        }
        self.tail.key_scores_into(q, scores);
    }

    fn value_combine(&self, weights: &[f32], out: &mut [f32]) {
        let d = self.d;
        let np = self.positions.len();
        for i in 0..np {
            let w = weights[i];
            if w == 0.0 {
                continue;
            }
            let row = &self.values[i * d..(i + 1) * d];
            for j in 0..d {
                out[j] += w * f16_bits_to_f32(row[j]);
            }
        }
        self.tail.value_combine(&weights[np..], out);
    }

    fn append(&mut self, position: u32, k: &[f32], v: &[f32]) {
        self.tail.append(position, k, v);
    }

    fn dim(&self) -> usize {
        self.d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng};

    fn block(n: usize, d: usize, seed: u64) -> KvBlock {
        let mut rng = Pcg64::new(seed);
        let mut k = vec![0.0f32; n * d];
        let mut v = vec![0.0f32; n * d];
        rng.fill_gaussian(&mut k);
        rng.fill_gaussian(&mut v);
        KvBlock::new(k, v, n, d)
    }

    #[test]
    fn snapkv_respects_budget_and_memory() {
        let b = block(64, 16, 1);
        let mut rng = Pcg64::new(2);
        let mut q = vec![0.0f32; 4 * 16];
        rng.fill_gaussian(&mut q);
        let kv = EvictionCompressor::snapkv(0.25).compress(&b, &q);
        assert_eq!(kv.n_tokens(), 16);
        let ratio = kv.memory_bytes() as f64 / b.fp16_bytes() as f64;
        assert!(ratio < 0.35, "ratio {ratio}");
    }

    #[test]
    fn snapkv_keeps_highly_attended_token() {
        let d = 16;
        let mut b = block(128, d, 3);
        let mut rng = Pcg64::new(4);
        let mut q = vec![0.0f32; d];
        rng.fill_gaussian(&mut q);
        // Token 10 is what the query looks for.
        for j in 0..d {
            b.keys[10 * d + j] = q[j] * 6.0;
        }
        let kv = EvictionCompressor::snapkv(0.25).compress(&b, &q);
        assert!(kv.positions().contains(&10), "needle must survive SnapKV");
    }

    #[test]
    fn streamingllm_keeps_sinks_and_recent_only() {
        let b = block(100, 8, 5);
        let kv = EvictionCompressor::streamingllm(0.2).compress(&b, &[]);
        let pos = kv.positions();
        assert_eq!(pos.len(), 20);
        assert_eq!(&pos[..4], &[0, 1, 2, 3]);
        assert_eq!(*pos.last().unwrap(), 99);
        // A middle token (the needle zone) is gone — StreamingLLM's known
        // failure mode on NIAH.
        assert!(!pos.contains(&50));
    }

    #[test]
    fn pyramid_budget_decays_with_layer() {
        let b = block(96, 8, 6);
        let q = vec![0.0f32; 8];
        let low = EvictionCompressor::pyramidkv(0.25, 0, 8).compress(&b, &q);
        let high = EvictionCompressor::pyramidkv(0.25, 7, 8).compress(&b, &q);
        assert!(
            low.n_tokens() > high.n_tokens(),
            "layer0 {} vs layer7 {}",
            low.n_tokens(),
            high.n_tokens()
        );
    }

    #[test]
    fn headkv_budget_scales_with_importance() {
        let b = block(96, 8, 7);
        let q = vec![0.0f32; 8];
        let hot = EvictionCompressor::headkv(0.25, 1.0).compress(&b, &q);
        let cold = EvictionCompressor::headkv(0.25, 0.0).compress(&b, &q);
        assert!(hot.n_tokens() > cold.n_tokens());
    }

    #[test]
    fn appended_tail_visible() {
        let b = block(32, 8, 8);
        let mut kv = EvictionCompressor::snapkv(0.25).compress(&b, &[]);
        let before = kv.n_tokens();
        kv.append(32, &vec![1.0; 8], &vec![1.0; 8]);
        assert_eq!(kv.n_tokens(), before + 1);
        assert_eq!(*kv.positions().last().unwrap(), 32);
    }

    #[test]
    fn empty_obs_queries_still_works() {
        // Without observation queries the scorer returns zeros →
        // selection degenerates to "recent + arbitrary", but must not panic.
        let b = block(40, 8, 9);
        let kv = EvictionCompressor::snapkv(0.25).compress(&b, &[]);
        assert_eq!(kv.n_tokens(), 10);
    }
}
