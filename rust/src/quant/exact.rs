//! Exact (FP16) cache — the paper's "Exact (16 bits)" reference row.
//!
//! Stores all prefill keys/values as f16 bit patterns (matching the
//! Llama-3.1 bf16/fp16 deployment the paper measures against) and serves
//! scores by converting on the fly.

use crate::quant::compressor::{CompressedKv, FpTail, KvBlock, KvCompressor};
use crate::quant::fp16::{encode_f16, f16_bits_to_f32};

/// Factory for exact-fp16 caches.
#[derive(Clone, Debug, Default)]
pub struct ExactCompressor;

impl KvCompressor for ExactCompressor {
    fn name(&self) -> String {
        "exact".into()
    }

    fn compress(&self, block: &KvBlock, _obs_queries: &[f32]) -> Box<dyn CompressedKv> {
        Box::new(ExactKv {
            d: block.d,
            positions: (0..block.n as u32).collect(),
            keys: encode_f16(&block.keys),
            values: encode_f16(&block.values),
            tail: FpTail::new(block.d),
        })
    }

    fn target_ratio(&self) -> f64 {
        1.0
    }
}

/// The fp16 store.
pub struct ExactKv {
    d: usize,
    positions: Vec<u32>,
    keys: Vec<u16>,
    values: Vec<u16>,
    tail: FpTail,
}

impl CompressedKv for ExactKv {
    fn n_tokens(&self) -> usize {
        self.positions.len() + self.tail.len()
    }

    fn positions(&self) -> Vec<u32> {
        let mut p = self.positions.clone();
        p.extend_from_slice(&self.tail.positions);
        p
    }

    fn memory_bytes(&self) -> usize {
        (self.keys.len() + self.values.len()) * 2 + self.tail.memory_bytes()
    }

    // analyze: allow(hot_path_alloc, "legacy per-sequence heap path: pushes into the caller's amortized scores buffer; the pool substrate is the serving default")
    fn key_scores(&self, q: &[f32], scores: &mut Vec<f32>) {
        assert_eq!(q.len(), self.d);
        scores.clear();
        let d = self.d;
        for i in 0..self.positions.len() {
            let row = &self.keys[i * d..(i + 1) * d];
            let mut s = 0.0f32;
            for j in 0..d {
                s += f16_bits_to_f32(row[j]) * q[j];
            }
            scores.push(s);
        }
        self.tail.key_scores_into(q, scores);
    }

    fn value_combine(&self, weights: &[f32], out: &mut [f32]) {
        let d = self.d;
        let np = self.positions.len();
        assert_eq!(weights.len(), self.n_tokens());
        for i in 0..np {
            let w = weights[i];
            if w == 0.0 {
                continue;
            }
            let row = &self.values[i * d..(i + 1) * d];
            for j in 0..d {
                out[j] += w * f16_bits_to_f32(row[j]);
            }
        }
        self.tail.value_combine(&weights[np..], out);
    }

    fn append(&mut self, position: u32, k: &[f32], v: &[f32]) {
        self.tail.append(position, k, v);
    }

    fn dim(&self) -> usize {
        self.d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng};

    fn block(n: usize, d: usize, seed: u64) -> KvBlock {
        let mut rng = Pcg64::new(seed);
        let mut k = vec![0.0f32; n * d];
        let mut v = vec![0.0f32; n * d];
        rng.fill_gaussian(&mut k);
        rng.fill_gaussian(&mut v);
        KvBlock::new(k, v, n, d)
    }

    #[test]
    fn scores_match_f32_within_fp16() {
        let b = block(16, 32, 1);
        let kv = ExactCompressor.compress(&b, &[]);
        let mut rng = Pcg64::new(2);
        let mut q = vec![0.0f32; 32];
        rng.fill_gaussian(&mut q);
        let mut scores = Vec::new();
        kv.key_scores(&q, &mut scores);
        for i in 0..16 {
            let want = crate::math::linalg::dot(b.key(i), &q);
            assert!((scores[i] - want).abs() < 0.05, "{} vs {}", scores[i], want);
        }
    }

    #[test]
    fn memory_is_fp16_footprint() {
        let b = block(16, 32, 3);
        let kv = ExactCompressor.compress(&b, &[]);
        assert_eq!(kv.memory_bytes(), b.fp16_bytes());
    }

    #[test]
    fn append_extends_positions_and_scores() {
        let d = 8;
        let b = block(4, d, 4);
        let mut kv = ExactCompressor.compress(&b, &[]);
        let k = vec![1.0f32; d];
        let v = vec![2.0f32; d];
        kv.append(4, &k, &v);
        assert_eq!(kv.n_tokens(), 5);
        assert_eq!(kv.positions(), vec![0, 1, 2, 3, 4]);
        let q = vec![1.0f32; d];
        let mut scores = Vec::new();
        kv.key_scores(&q, &mut scores);
        assert!((scores[4] - d as f32).abs() < 1e-3);
        let mut out = vec![0.0f32; d];
        kv.value_combine(&[0.0, 0.0, 0.0, 0.0, 1.0], &mut out);
        assert!((out[0] - 2.0).abs() < 1e-3);
    }

    #[test]
    fn dequant_keys_default_impl_matches_storage() {
        let b = block(3, 8, 5);
        let kv = ExactCompressor.compress(&b, &[]);
        let keys = kv.dequant_keys();
        for (a, b) in keys.iter().zip(&b.keys) {
            assert!((a - b).abs() < 0.01);
        }
    }
}
