//! IEEE-754 binary16 conversion (the `half` crate is unavailable offline).
//!
//! Used for radius storage in the PolarQuant layout (paper §4.1: radii kept
//! in b_FPN = 16 bits), for the Exact-FP16 baseline cache, and for the
//! generation-tail storage. Round-to-nearest-even, with correct handling of
//! subnormals, infinities and NaN.

/// Convert f32 → f16 bits (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // Inf / NaN. Preserve NaN-ness with a quiet mantissa bit.
        return sign | 0x7C00 | if mant != 0 { 0x0200 } else { 0 };
    }
    // Unbiased exponent.
    let e = exp - 127;
    if e > 15 {
        // Overflow → ±inf.
        return sign | 0x7C00;
    }
    if e >= -14 {
        // Normal f16. Round mantissa 23 → 10 bits.
        let mant16 = mant >> 13;
        let rest = mant & 0x1FFF;
        let halfway = 0x1000;
        let mut out = sign | (((e + 15) as u16) << 10) | mant16 as u16;
        if rest > halfway || (rest == halfway && (mant16 & 1) == 1) {
            out = out.wrapping_add(1); // may carry into exponent — that's correct
        }
        return out;
    }
    if e >= -24 {
        // Subnormal f16.
        let full = mant | 0x80_0000; // implicit leading 1
        let shift = (-14 - e) as u32 + 13;
        let mant16 = full >> shift;
        let rest = full & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let mut out = sign | mant16 as u16;
        if rest > halfway || (rest == halfway && (mant16 & 1) == 1) {
            out = out.wrapping_add(1);
        }
        return out;
    }
    // Underflow → ±0.
    sign
}

/// Convert f16 bits → f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign, // ±0
        (0, m) => {
            // Subnormal: value = m · 2⁻²⁴. Normalize around the highest
            // set bit hb (0..=9): value = 2^(hb−24) · (m / 2^hb).
            let hb = 31 - m.leading_zeros(); // position of highest set bit
            let e = 103 + hb; // 127 + (hb − 24)
            let frac = (m ^ (1 << hb)) << (23 - hb);
            sign | (e << 23) | frac
        }
        (0x1F, 0) => sign | 0x7F80_0000,              // ±inf
        (0x1F, m) => sign | 0x7F80_0000 | (m << 13),  // NaN
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// Round-trip an f32 through f16 (the storage loss an fp16 cache incurs).
pub fn quantize_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// Convert a slice to f16 bits.
pub fn encode_f16(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| f32_to_f16_bits(x)).collect()
}

/// Convert f16 bits back into an f32 buffer.
pub fn decode_f16_into(hs: &[u16], out: &mut [f32]) {
    assert_eq!(hs.len(), out.len());
    for (o, &h) in out.iter_mut().zip(hs) {
        *o = f16_bits_to_f32(h);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng};

    #[test]
    fn exact_small_integers_roundtrip() {
        for i in -2048i32..=2048 {
            let x = i as f32;
            assert_eq!(quantize_f16(x), x, "f16 must be exact for |int| <= 2048: {i}");
        }
    }

    #[test]
    fn known_bit_patterns() {
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(65504.0), 0x7BFF); // max finite f16
        assert_eq!(f32_to_f16_bits(1e6), 0x7C00); // overflow → inf
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        assert!(f16_bits_to_f32(0x7E00).is_nan());
    }

    #[test]
    fn subnormals_roundtrip() {
        let min_sub = f16_bits_to_f32(0x0001); // 2^-24
        assert!((min_sub - 2.0f32.powi(-24)).abs() < 1e-12);
        assert_eq!(f32_to_f16_bits(min_sub), 0x0001);
        let x = 3.0 * 2.0f32.powi(-24);
        let b = f32_to_f16_bits(x);
        assert_eq!(f16_bits_to_f32(b), x);
    }

    #[test]
    fn relative_error_bounded_for_normals() {
        let mut rng = Pcg64::new(17);
        for _ in 0..20_000 {
            let x = (rng.gaussian() * 10.0) as f32;
            if x.abs() < 1e-4 {
                continue;
            }
            let q = quantize_f16(x);
            let rel = ((q - x) / x).abs();
            assert!(rel < 1.0 / 1024.0, "x={x} q={q} rel={rel}");
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1+2^-10 → ties to even (1.0).
        let x = 1.0 + 2.0f32.powi(-11);
        assert_eq!(quantize_f16(x), 1.0);
        // 1 + 3·2^-11 is halfway between 1+2^-10 and 1+2^-9 → ties to even (1+2^-9).
        let y = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(quantize_f16(y), 1.0 + 2.0 * 2.0f32.powi(-10));
    }

    #[test]
    fn nan_preserved() {
        assert!(quantize_f16(f32::NAN).is_nan());
    }

    #[test]
    fn slice_encode_decode() {
        let xs = [0.5f32, -1.25, 3.75, 100.0];
        let hs = encode_f16(&xs);
        let mut out = [0.0f32; 4];
        decode_f16_into(&hs, &mut out);
        assert_eq!(xs, out);
    }
}
