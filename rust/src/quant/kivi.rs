//! KIVI baseline [26]: tuning-free asymmetric 2-bit quantization.
//!
//! KIVI's recipe: quantize the **key** cache *per-channel* (group along the
//! token axis within each channel — key channels have outlier magnitudes
//! that per-token grouping would smear) and the **value** cache
//! *per-token*; keep a full-precision residual window of the most recent
//! tokens. Every group stores a zero point and scale in fp16 — exactly the
//! normalization overhead PolarQuant's analysis removes, and the reason
//! KIVI's bits/coordinate is higher than its nominal 2 bits
//! (2 + 2·16/G extra bits per coordinate for group size G).

use crate::quant::compressor::{CompressedKv, FpTail, KvBlock, KvCompressor};
use crate::quant::fp16::{f16_bits_to_f32, quantize_f16};

/// KIVI configuration.
#[derive(Clone, Debug)]
pub struct KiviConfig {
    /// Bits per quantized coordinate (paper: 2).
    pub bits: u8,
    /// Group size G along the grouped axis (paper: 32 or 128).
    pub group: usize,
    /// Full-precision residual window (most recent tokens kept fp16).
    pub residual: usize,
}

impl Default for KiviConfig {
    fn default() -> Self {
        Self { bits: 2, group: 32, residual: 32 }
    }
}

/// The compressor.
#[derive(Clone, Debug, Default)]
pub struct KiviCompressor {
    pub cfg: KiviConfig,
}

impl KiviCompressor {
    pub fn new(cfg: KiviConfig) -> Self {
        Self { cfg }
    }
}

/// One quantized group: codes plus fp16 zero/scale. Shared with the
/// page-native KIVI codec (`kvcache::codec::KiviPageCodec`), which
/// stores these constants inside each token slot.
#[derive(Clone, Debug)]
pub(crate) struct Group {
    /// zero point (minimum), fp16-rounded.
    pub(crate) zero: f32,
    /// scale = (max−min)/(2^b−1), fp16-rounded.
    pub(crate) scale: f32,
}

pub(crate) fn quantize_group(xs: &[f32], bits: u8) -> (Group, Vec<u8>) {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in xs {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if !lo.is_finite() {
        lo = 0.0;
        hi = 0.0;
    }
    let levels = (1u32 << bits) - 1;
    let zero = quantize_f16(lo);
    let scale = quantize_f16(((hi - lo) / levels as f32).max(1e-8));
    let codes = xs
        .iter()
        .map(|&x| (((x - zero) / scale).round().clamp(0.0, levels as f32)) as u8)
        // analyze: allow(hot_path_alloc, "group quantization runs at append/encode time, once per stored token, not in the per-step scoring loop")
        .collect();
    (Group { zero, scale }, codes)
}

#[inline]
fn dequant(code: u8, g: &Group) -> f32 {
    dequant_code(code, g.zero, g.scale)
}

/// Dequantize one code against explicit (zero, scale) constants — the
/// slot-resident form the page codec reads back from fp16 headers.
#[inline]
pub(crate) fn dequant_code(code: u8, zero: f32, scale: f32) -> f32 {
    zero + scale * code as f32
}

impl KvCompressor for KiviCompressor {
    fn name(&self) -> String {
        "kivi".into()
    }

    fn compress(&self, block: &KvBlock, _obs: &[f32]) -> Box<dyn CompressedKv> {
        let d = block.d;
        let n = block.n;
        let cfg = &self.cfg;
        let res = cfg.residual.min(n);
        let nq = n - res; // tokens quantized; most recent `res` stay fp16

        // Keys: per-channel groups along tokens. codes stored
        // channel-major: key_codes[c][t] for t in 0..nq.
        let mut key_groups: Vec<Group> = Vec::new();
        let mut key_codes = vec![0u8; nq * d];
        let groups_per_channel = nq.div_ceil(cfg.group).max(if nq > 0 { 1 } else { 0 });
        let mut chan = vec![0.0f32; cfg.group];
        for c in 0..d {
            for g in 0..groups_per_channel {
                let t0 = g * cfg.group;
                let t1 = ((g + 1) * cfg.group).min(nq);
                let m = t1 - t0;
                for (slot, t) in (t0..t1).enumerate() {
                    chan[slot] = block.keys[t * d + c];
                }
                let (grp, codes) = quantize_group(&chan[..m], cfg.bits);
                key_groups.push(grp);
                for (slot, t) in (t0..t1).enumerate() {
                    key_codes[c * nq + t] = codes[slot];
                }
            }
        }

        // Values: per-token groups along channels.
        let mut val_groups: Vec<Group> = Vec::with_capacity(nq * d.div_ceil(cfg.group));
        let mut val_codes = vec![0u8; nq * d];
        for t in 0..nq {
            let row = block.value(t);
            for g in 0..d.div_ceil(cfg.group) {
                let c0 = g * cfg.group;
                let c1 = ((g + 1) * cfg.group).min(d);
                let (grp, codes) = quantize_group(&row[c0..c1], cfg.bits);
                val_groups.push(grp);
                val_codes[t * d + c0..t * d + c1].copy_from_slice(&codes);
            }
        }

        // Residual window: fp16 exact.
        let mut tail = FpTail::new(d);
        for t in nq..n {
            tail.append(t as u32, block.key(t), block.value(t));
        }

        Box::new(KiviKv {
            d,
            nq,
            bits: cfg.bits,
            group: cfg.group,
            key_groups,
            key_codes,
            val_groups,
            val_codes,
            tail,
        })
    }

    fn target_ratio(&self) -> f64 {
        // ~ (b + 2·16/G)/16 plus the residual window.
        (self.cfg.bits as f64 + 32.0 / self.cfg.group as f64) / 16.0
    }
}

/// KIVI store: channel-major key codes, token-major value codes.
pub struct KiviKv {
    d: usize,
    nq: usize,
    bits: u8,
    group: usize,
    key_groups: Vec<Group>,
    key_codes: Vec<u8>,
    val_groups: Vec<Group>,
    val_codes: Vec<u8>,
    tail: FpTail,
}

impl CompressedKv for KiviKv {
    fn n_tokens(&self) -> usize {
        self.nq + self.tail.len()
    }

    fn positions(&self) -> Vec<u32> {
        let mut p: Vec<u32> = (0..self.nq as u32).collect();
        p.extend_from_slice(&self.tail.positions);
        p
    }

    fn memory_bytes(&self) -> usize {
        // Packed codes at `bits` per entry + fp16 zero/scale per group.
        let code_bytes = |n_codes: usize| (n_codes * self.bits as usize).div_ceil(8);
        code_bytes(self.key_codes.len())
            + code_bytes(self.val_codes.len())
            + (self.key_groups.len() + self.val_groups.len()) * 4
            + self.tail.memory_bytes()
    }

    fn key_scores(&self, q: &[f32], scores: &mut Vec<f32>) {
        scores.clear();
        scores.resize(self.nq, 0.0);
        let d = self.d;
        let nq = self.nq;
        if nq > 0 {
            let gpc = nq.div_ceil(self.group);
            for c in 0..d {
                let qc = q[c];
                if qc == 0.0 {
                    continue;
                }
                let codes = &self.key_codes[c * nq..(c + 1) * nq];
                for g in 0..gpc {
                    let grp = &self.key_groups[c * gpc + g];
                    let t0 = g * self.group;
                    let t1 = ((g + 1) * self.group).min(nq);
                    for t in t0..t1 {
                        scores[t] += qc * dequant(codes[t], grp);
                    }
                }
            }
        }
        self.tail.key_scores_into(q, scores);
    }

    fn value_combine(&self, weights: &[f32], out: &mut [f32]) {
        let d = self.d;
        let gpr = d.div_ceil(self.group);
        for t in 0..self.nq {
            let w = weights[t];
            if w == 0.0 {
                continue;
            }
            let row = &self.val_codes[t * d..(t + 1) * d];
            for g in 0..gpr {
                let grp = &self.val_groups[t * gpr + g];
                let c0 = g * self.group;
                let c1 = ((g + 1) * self.group).min(d);
                for c in c0..c1 {
                    out[c] += w * dequant(row[c], grp);
                }
            }
        }
        self.tail.value_combine(&weights[self.nq..], out);
    }

    fn append(&mut self, position: u32, k: &[f32], v: &[f32]) {
        self.tail.append(position, k, v);
    }

    fn dim(&self) -> usize {
        self.d
    }
}

// Silence unused warning for f16 import used in tests.
#[allow(unused)]
fn _use(h: u16) -> f32 {
    f16_bits_to_f32(h)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng};

    fn block(n: usize, d: usize, seed: u64) -> KvBlock {
        let mut rng = Pcg64::new(seed);
        let mut k = vec![0.0f32; n * d];
        let mut v = vec![0.0f32; n * d];
        rng.fill_gaussian(&mut k);
        rng.fill_gaussian(&mut v);
        KvBlock::new(k, v, n, d)
    }

    #[test]
    fn group_quantizer_hits_extremes() {
        let xs = [0.0f32, 1.0, 2.0, 3.0];
        let (g, codes) = quantize_group(&xs, 2);
        assert_eq!(codes, vec![0, 1, 2, 3]);
        assert!((dequant(codes[0], &g) - 0.0).abs() < 1e-3);
        assert!((dequant(codes[3], &g) - 3.0).abs() < 2e-3);
    }

    #[test]
    fn scores_track_exact_within_2bit_noise() {
        let d = 32;
        let n = 128;
        let b = block(n, d, 1);
        let kv = KiviCompressor::default().compress(&b, &[]);
        let mut rng = Pcg64::new(2);
        let mut q = vec![0.0f32; d];
        rng.fill_gaussian(&mut q);
        let mut scores = Vec::new();
        kv.key_scores(&q, &mut scores);
        assert_eq!(scores.len(), n);
        let mut err = 0.0f64;
        let mut mag = 0.0f64;
        for t in 0..n {
            let want = crate::math::linalg::dot(b.key(t), &q);
            err += ((scores[t] - want) as f64).powi(2);
            mag += (want as f64).powi(2);
        }
        let rel = (err / mag).sqrt();
        assert!(rel < 0.35, "2-bit KIVI relative score error {rel}");
        // Residual window tokens are exact (fp16).
        let t = n - 1;
        let want = crate::math::linalg::dot(b.key(t), &q);
        assert!((scores[t] - want).abs() < 0.05);
    }

    #[test]
    fn memory_ratio_near_nominal() {
        let d = 64;
        let n = 512;
        let b = block(n, d, 3);
        let kv = KiviCompressor::default().compress(&b, &[]);
        let ratio = kv.memory_bytes() as f64 / b.fp16_bytes() as f64;
        // 2-bit + overhead + 32-token residual on 512 → ~0.25.
        assert!(ratio > 0.15 && ratio < 0.32, "ratio {ratio}");
    }

    #[test]
    fn overhead_bits_exceed_nominal_bits() {
        // The normalization-overhead claim: actual bits/coord > 2.
        let d = 64;
        let n = 512;
        let b = block(n, d, 4);
        let cfg = KiviConfig { bits: 2, group: 32, residual: 0 };
        let kv = KiviCompressor::new(cfg).compress(&b, &[]);
        let bits_per_coord = kv.memory_bytes() as f64 * 8.0 / (2 * n * d) as f64;
        assert!(
            bits_per_coord > 2.9 && bits_per_coord < 3.2,
            "KIVI true cost ≈ 2 + 2·16/32 = 3 bits/coord, got {bits_per_coord}"
        );
    }

    #[test]
    fn value_combine_matches_exact_within_noise() {
        let d = 16;
        let n = 64;
        let b = block(n, d, 5);
        let kv = KiviCompressor::default().compress(&b, &[]);
        let mut rng = Pcg64::new(6);
        let mut w = vec![0.0f32; n];
        rng.fill_uniform(&mut w, 0.0, 1.0);
        let s: f32 = w.iter().sum();
        for x in w.iter_mut() {
            *x /= s;
        }
        let mut got = vec![0.0f32; d];
        kv.value_combine(&w, &mut got);
        let mut want = vec![0.0f32; d];
        for t in 0..n {
            for c in 0..d {
                want[c] += w[t] * b.values[t * d + c];
            }
        }
        let rel = crate::util::stats::rel_l2_error(&got, &want);
        assert!(rel < 0.2, "rel {rel}");
    }

    #[test]
    fn short_sequences_all_residual() {
        let b = block(8, 16, 7);
        let kv = KiviCompressor::default().compress(&b, &[]);
        // n < residual ⇒ everything fp16, nothing quantized.
        assert_eq!(kv.n_tokens(), 8);
        let q = vec![1.0f32; 16];
        let mut scores = Vec::new();
        kv.key_scores(&q, &mut scores);
        let want = crate::math::linalg::dot(b.key(0), &q);
        assert!((scores[0] - want).abs() < 0.05);
    }
}
