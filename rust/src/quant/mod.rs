//! KV compression methods: the common [`compressor::KvCompressor`]
//! interface, the fp16 substrate, and every baseline the paper compares
//! against (Table 1 / Fig. 3): KIVI, QJL, SnapKV, PyramidKV, StreamingLLM,
//! HeadKV, plus Exact-FP16 and PolarQuant itself behind the same trait.
//!
//! Two cache substrates build on these primitives:
//!
//! * the **page-native** serving path
//!   ([`crate::kvcache::codec::PageCodec`]): quantization methods whose
//!   encoded token is a fixed, self-contained byte slot (polarquant,
//!   exact/fp16, a per-token KIVI variant) live directly in
//!   [`crate::kvcache::paged::PagedPool`] pages and are shared
//!   zero-copy across requests;
//! * the **legacy heap** path ([`compressor::CompressedKv`] boxes, used
//!   by the eval harnesses and by methods that cannot be slot-shaped:
//!   the token-evicting SnapKV family and the per-sequence-codebook
//!   online PolarQuant variant).

pub mod compressor;
pub mod eviction;
pub mod exact;
pub mod fp16;
pub mod kivi;
pub mod polar_kv;
pub mod qjl;
pub mod registry;
