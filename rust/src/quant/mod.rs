//! KV compression methods: the common [`compressor::KvCompressor`]
//! interface, the fp16 substrate, and every baseline the paper compares
//! against (Table 1 / Fig. 3): KIVI, QJL, SnapKV, PyramidKV, StreamingLLM,
//! HeadKV, plus Exact-FP16 and PolarQuant itself behind the same trait.

pub mod compressor;
pub mod eviction;
pub mod exact;
pub mod fp16;
pub mod kivi;
pub mod polar_kv;
pub mod qjl;
pub mod registry;
