//! PolarQuant as a KV-cache compression method (paper §4).
//!
//! Wraps [`PolarQuantizer`] behind the [`KvCompressor`] interface used by
//! the eval harnesses. Three paper variants:
//!
//! * `PolarQuant`      — no preconditioning, offline analytic codebooks;
//! * `PolarQuant-R (offline)` — rotation + shared analytic codebooks;
//! * `PolarQuant-R (online)`  — rotation + per-block k-means codebooks
//!   fitted on the prefill angles (paper §4.1 online construction).
//!
//! The decode hot path uses the preconditioned-basis trick: queries are
//! rotated once per step, cached keys are reconstructed without applying
//! Rᵀ (see `polar::quantizer`).

use crate::math::rotation::PreconditionKind;
use crate::polar::quantizer::{PolarConfig, PolarQuantizer, QuantizedVector};
use crate::quant::compressor::{CompressedKv, FpTail, KvBlock, KvCompressor};

/// Codebook construction mode (paper §4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CodebookMode {
    /// Precomputed from the analytic angle law; shared across blocks.
    Offline,
    /// k-means++ on this block's angles at compress time.
    Online,
}

/// PolarQuant variant descriptor.
#[derive(Clone, Debug)]
pub struct PolarVariant {
    pub precondition: PreconditionKind,
    pub codebooks: CodebookMode,
}

impl PolarVariant {
    /// Paper row "PolarQuant" (no rotation, offline books).
    pub fn plain() -> Self {
        Self { precondition: PreconditionKind::None, codebooks: CodebookMode::Offline }
    }

    /// Paper row "PolarQuant-R (offline)".
    pub fn r_offline() -> Self {
        Self { precondition: PreconditionKind::Haar, codebooks: CodebookMode::Offline }
    }

    /// Paper row "PolarQuant-R (online)".
    pub fn r_online() -> Self {
        Self { precondition: PreconditionKind::Haar, codebooks: CodebookMode::Online }
    }
}

/// The compressor. Holds a prototype config; for the offline modes the
/// quantizer (rotation + codebooks) is built once and shared.
pub struct PolarKvCompressor {
    pub variant: PolarVariant,
    pub cfg: PolarConfig,
    /// Shared quantizer for offline codebooks (None → build per block).
    shared: Option<PolarQuantizer>,
}

impl PolarKvCompressor {
    pub fn new(d: usize, variant: PolarVariant) -> Self {
        let mut cfg = PolarConfig::paper_default(d);
        cfg.precondition = variant.precondition;
        let shared = match variant.codebooks {
            CodebookMode::Offline => Some(PolarQuantizer::new_offline(cfg.clone())),
            CodebookMode::Online => None,
        };
        Self { variant, cfg, shared }
    }

    /// Custom layout (ablations: level count / bit allocation).
    pub fn with_config(cfg: PolarConfig, variant: PolarVariant) -> Self {
        let shared = match variant.codebooks {
            CodebookMode::Offline => Some(PolarQuantizer::new_offline(cfg.clone())),
            CodebookMode::Online => None,
        };
        Self { variant, cfg, shared }
    }
}

impl KvCompressor for PolarKvCompressor {
    fn name(&self) -> String {
        match (self.variant.precondition, self.variant.codebooks) {
            (PreconditionKind::None, _) => "polarquant".into(),
            (_, CodebookMode::Offline) => "polarquant-r-offline".into(),
            (_, CodebookMode::Online) => "polarquant-r-online".into(),
        }
    }

    fn compress(&self, block: &KvBlock, _obs: &[f32]) -> Box<dyn CompressedKv> {
        let quantizer = match &self.shared {
            Some(q) => q.clone(),
            None => {
                // Online: fit codebooks on this block's keys+values jointly
                // (the paper clusters the polar-transformed prefill angles
                // per layer; K and V share the preconditioner).
                let mut calib =
                    Vec::with_capacity(block.keys.len() + block.values.len());
                calib.extend_from_slice(&block.keys);
                calib.extend_from_slice(&block.values);
                PolarQuantizer::new_online(self.cfg.clone(), &calib)
            }
        };
        let keys: Vec<QuantizedVector> =
            block.keys.chunks(block.d).map(|r| quantizer.encode(r)).collect();
        let values: Vec<QuantizedVector> =
            block.values.chunks(block.d).map(|r| quantizer.encode(r)).collect();
        // Codebook storage: charged once per block for the online variant
        // (it is block-specific); the offline books are global constants.
        let codebook_bytes = match self.variant.codebooks {
            CodebookMode::Offline => 0,
            CodebookMode::Online => quantizer
                .codebooks
                .books
                .iter()
                .map(|b| b.centroids.len() * 2)
                .sum(),
        };
        Box::new(PolarKv {
            d: block.d,
            quantizer,
            keys,
            values,
            codebook_bytes,
            tail: FpTail::new(block.d),
        })
    }

    fn target_ratio(&self) -> f64 {
        self.cfg.bits_per_coordinate() / 16.0
    }
}

/// PolarQuant store: packed codes per token + fp16 radii.
pub struct PolarKv {
    d: usize,
    quantizer: PolarQuantizer,
    keys: Vec<QuantizedVector>,
    values: Vec<QuantizedVector>,
    codebook_bytes: usize,
    tail: FpTail,
}

impl CompressedKv for PolarKv {
    fn n_tokens(&self) -> usize {
        self.keys.len() + self.tail.len()
    }

    fn positions(&self) -> Vec<u32> {
        let mut p: Vec<u32> = (0..self.keys.len() as u32).collect();
        p.extend_from_slice(&self.tail.positions);
        p
    }

    fn memory_bytes(&self) -> usize {
        let kv_bytes: usize = self
            .keys
            .iter()
            .chain(self.values.iter())
            .map(|q| q.storage_bytes())
            .sum();
        kv_bytes + self.codebook_bytes + self.tail.memory_bytes()
    }

    // analyze: allow(hot_path_alloc, "legacy per-sequence heap path: per-step prepared query and scratch; the pool substrate's codec scratch is the serving default")
    fn key_scores(&self, q: &[f32], scores: &mut Vec<f32>) {
        scores.clear();
        if self.quantizer.cfg.fits_fused_kernels() {
            // Fused path (§Perf): prepare the query once (rotation +
            // level-1 centroid table), then score each token by tree
            // contraction — no per-token reconstruction buffer, no trig.
            let prepared = self.quantizer.prepare_query(q);
            let mut scratch = Vec::with_capacity(self.d / 2);
            for k in &self.keys {
                scores.push(self.quantizer.score(&prepared, k, &mut scratch));
            }
        } else {
            // Past the fused kernels' stack capacity (d > 256): decode
            // each key in the preconditioned basis and dot against the
            // rotated query (⟨Rᵀy, q⟩ = ⟨y, Rq⟩) — correct for any dim.
            let mut rq = vec![0.0f32; self.d];
            self.quantizer.rotation.apply(q, &mut rq);
            let mut dec = vec![0.0f32; self.d];
            for k in &self.keys {
                self.quantizer.decode_preconditioned(k, &mut dec);
                scores.push(crate::math::linalg::dot(&dec, &rq));
            }
        }
        self.tail.key_scores_into(q, scores);
    }

    // analyze: allow(hot_path_alloc, "legacy per-sequence heap path: per-step accumulator buffers; the pool substrate's codec scratch is the serving default")
    fn value_combine(&self, weights: &[f32], out: &mut [f32]) {
        let d = self.d;
        let np = self.values.len();
        // Accumulate in the preconditioned basis, un-rotate once at the end
        // (linear, so Σ wᵢ Rᵀyᵢ = Rᵀ Σ wᵢ yᵢ) — one rotation per step
        // instead of one per token.
        let mut acc = vec![0.0f32; d];
        if self.quantizer.cfg.fits_fused_kernels() {
            for (i, v) in self.values.iter().enumerate() {
                let w = weights[i];
                if w == 0.0 {
                    continue;
                }
                self.quantizer.decode_scaled_accumulate(v, w, &mut acc);
            }
        } else {
            // Materialized fallback past the fused kernels' capacity:
            // decode then axpy — the chunked decode walk handles any dim.
            let mut dec = vec![0.0f32; d];
            for (i, v) in self.values.iter().enumerate() {
                let w = weights[i];
                if w == 0.0 {
                    continue;
                }
                self.quantizer.decode_preconditioned(v, &mut dec);
                for (a, &x) in acc.iter_mut().zip(dec.iter()) {
                    *a += w * x;
                }
            }
        }
        let mut unrot = vec![0.0f32; d];
        self.quantizer.rotation.apply_t(&acc, &mut unrot);
        crate::math::linalg::add_assign(out, &unrot);
        self.tail.value_combine(&weights[np..], out);
    }

    fn append(&mut self, position: u32, k: &[f32], v: &[f32]) {
        self.tail.append(position, k, v);
    }

    fn dim(&self) -> usize {
        self.d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, Rng};

    fn block(n: usize, d: usize, seed: u64) -> KvBlock {
        let mut rng = Pcg64::new(seed);
        let mut k = vec![0.0f32; n * d];
        let mut v = vec![0.0f32; n * d];
        rng.fill_gaussian(&mut k);
        rng.fill_gaussian(&mut v);
        KvBlock::new(k, v, n, d)
    }

    #[test]
    fn memory_ratio_is_paper_claim() {
        let d = 64;
        let n = 256;
        let b = block(n, d, 1);
        let kv = PolarKvCompressor::new(d, PolarVariant::r_offline()).compress(&b, &[]);
        let ratio = kv.memory_bytes() as f64 / b.fp16_bytes() as f64;
        // 3.875/16 = 0.2422 — the ×4.13 compression of §4.
        assert!((ratio - 3.875 / 16.0).abs() < 1e-6, "ratio {ratio}");
    }

    #[test]
    fn key_scores_close_to_exact() {
        let d = 64;
        let n = 64;
        let b = block(n, d, 2);
        let kv = PolarKvCompressor::new(d, PolarVariant::r_offline()).compress(&b, &[]);
        let mut rng = Pcg64::new(3);
        let mut q = vec![0.0f32; d];
        rng.fill_gaussian(&mut q);
        let mut got = Vec::new();
        kv.key_scores(&q, &mut got);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for t in 0..n {
            let want = crate::math::linalg::dot(b.key(t), &q);
            num += ((got[t] - want) as f64).powi(2);
            den += (want as f64).powi(2);
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.2, "polar score rel error {rel}");
    }

    #[test]
    fn all_three_variants_work_and_rank_sanely() {
        // On anisotropic data (what real KV looks like), -R variants must
        // beat plain PolarQuant on reconstruction-driven score error.
        let d = 64;
        let n = 96;
        let mut rng = Pcg64::new(4);
        let mut b = block(n, d, 5);
        // Make channels anisotropic + one outlier channel.
        for t in 0..n {
            for c in 0..d {
                b.keys[t * d + c] *= if c % 7 == 0 { 4.0 } else { 0.3 };
            }
            b.keys[t * d + 11] += 6.0;
        }
        let mut q = vec![0.0f32; d];
        rng.fill_gaussian(&mut q);
        let err = |variant: PolarVariant| {
            let kv = PolarKvCompressor::new(d, variant).compress(&b, &[]);
            let mut got = Vec::new();
            kv.key_scores(&q, &mut got);
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for t in 0..n {
                let want = crate::math::linalg::dot(b.key(t), &q);
                num += ((got[t] - want) as f64).powi(2);
                den += (want as f64).powi(2);
            }
            (num / den).sqrt()
        };
        let e_plain = err(PolarVariant::plain());
        let e_off = err(PolarVariant::r_offline());
        let e_on = err(PolarVariant::r_online());
        assert!(e_off < e_plain, "rotation must help: {e_off} vs {e_plain}");
        assert!(e_on < e_plain, "online must help: {e_on} vs {e_plain}");
    }

    #[test]
    fn value_combine_close_to_exact() {
        let d = 64;
        let n = 32;
        let b = block(n, d, 6);
        let kv = PolarKvCompressor::new(d, PolarVariant::r_offline()).compress(&b, &[]);
        let mut w = vec![0.0f32; n];
        w[7] = 0.6;
        w[20] = 0.4;
        let mut got = vec![0.0f32; d];
        kv.value_combine(&w, &mut got);
        let mut want = vec![0.0f32; d];
        for c in 0..d {
            want[c] = 0.6 * b.values[7 * d + c] + 0.4 * b.values[20 * d + c];
        }
        let rel = crate::util::stats::rel_l2_error(&got, &want);
        assert!(rel < 0.25, "rel {rel}");
    }

    #[test]
    fn tail_append_exact() {
        let d = 32;
        let b = block(8, d, 7);
        let mut kv = PolarKvCompressor::new(d, PolarVariant::r_offline()).compress(&b, &[]);
        let mut rng = Pcg64::new(8);
        let mut k = vec![0.0f32; d];
        let mut v = vec![0.0f32; d];
        rng.fill_gaussian(&mut k);
        rng.fill_gaussian(&mut v);
        kv.append(8, &k, &v);
        let mut scores = Vec::new();
        kv.key_scores(&k, &mut scores);
        let want = crate::math::linalg::dot(&k, &k);
        assert!(
            ((scores[8] - want) / want).abs() < 0.01,
            "tail is fp16-exact: {} vs {want}",
            scores[8]
        );
    }

    #[test]
    fn large_head_dim_served_without_panic() {
        // Regression: d = 512 passes the old radii gate but overflows
        // the fused kernels' stack scratch (release-mode OOB panic in
        // `accumulate_with`). The legacy compressor must detect the
        // capacity miss and serve scores/combines via the materialized
        // decode path instead.
        let d = 512;
        let n = 6;
        let b = block(n, d, 11);
        let kv = PolarKvCompressor::new(d, PolarVariant::r_offline()).compress(&b, &[]);
        let mut rng = Pcg64::new(12);
        let mut q = vec![0.0f32; d];
        rng.fill_gaussian(&mut q);
        let mut scores = Vec::new();
        kv.key_scores(&q, &mut scores);
        assert_eq!(scores.len(), n);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for t in 0..n {
            let want = crate::math::linalg::dot(b.key(t), &q);
            num += ((scores[t] - want) as f64).powi(2);
            den += (want as f64).powi(2);
        }
        let rel = (num / den).sqrt();
        assert!(rel < 0.2, "d=512 score rel error {rel}");
        let mut w = vec![0.0f32; n];
        w[1] = 0.5;
        w[4] = 0.5;
        let mut got = vec![0.0f32; d];
        kv.value_combine(&w, &mut got);
        let mut want = vec![0.0f32; d];
        for c in 0..d {
            want[c] = 0.5 * b.values[d + c] + 0.5 * b.values[4 * d + c];
        }
        let rel = crate::util::stats::rel_l2_error(&got, &want);
        assert!(rel < 0.25, "d=512 combine rel {rel}");
    }

    #[test]
    fn online_codebook_bytes_charged() {
        let d = 32;
        let b = block(64, d, 9);
        let on = PolarKvCompressor::new(d, PolarVariant::r_online()).compress(&b, &[]);
        let off = PolarKvCompressor::new(d, PolarVariant::r_offline()).compress(&b, &[]);
        assert!(on.memory_bytes() > off.memory_bytes());
        // Difference is exactly the codebook: (16+4+4+4) centroids × 2B.
        assert_eq!(on.memory_bytes() - off.memory_bytes(), 2 * (16 + 4 + 4 + 4));
    }
}
