//! QJL baseline [41]: 1-bit quantized Johnson–Lindenstrauss transform.
//!
//! Keys: store sign(S·k) (1 bit per sketch coordinate) plus ‖k‖ in fp16.
//! The inner product is estimated from the angle between sign patterns:
//!   ⟨k, q⟩ ≈ ‖k‖·‖q‖·cos(π·hamming/m)  — the classic SimHash estimator,
//! which is what makes QJL data-oblivious and normalization-free (its
//! overhead is one fp16 norm per token — the property PolarQuant shares).
//! Values: per-token 8-bit quantization (QJL quantizes values by standard
//! integer quantization since value outliers are token-aligned).

use crate::quant::compressor::{CompressedKv, FpTail, KvBlock, KvCompressor};
use crate::quant::fp16::{f16_bits_to_f32, f32_to_f16_bits, quantize_f16};
use crate::util::rng::{Pcg64, Rng};

/// QJL configuration.
#[derive(Clone, Debug)]
pub struct QjlConfig {
    /// Sketch dimension m (bits per key). The QJL paper uses m ≈ 2–4×d.
    pub sketch_dim: usize,
    /// Value bits (paper: 8 per coordinate, per-token normalization).
    pub value_bits: u8,
    pub seed: u64,
}

impl QjlConfig {
    pub fn for_dim(d: usize) -> Self {
        Self { sketch_dim: 3 * d, value_bits: 8, seed: 0x514a4c } // "QJL"
    }
}

/// The compressor; holds the shared Gaussian sketch.
pub struct QjlCompressor {
    cfg: QjlConfig,
    d: usize,
    /// Row-major (m × d) Gaussian sketch.
    sketch: Vec<f32>,
}

impl QjlCompressor {
    pub fn new(d: usize, cfg: QjlConfig) -> Self {
        let mut rng = Pcg64::new(cfg.seed);
        let sketch = (0..cfg.sketch_dim * d).map(|_| rng.gaussian_f32()).collect();
        Self { cfg, d, sketch }
    }

    pub fn for_dim(d: usize) -> Self {
        Self::new(d, QjlConfig::for_dim(d))
    }

    fn sketch_signs(&self, x: &[f32]) -> Vec<u64> {
        let m = self.cfg.sketch_dim;
        let d = self.d;
        let mut words = vec![0u64; m.div_ceil(64)];
        for i in 0..m {
            let s = crate::math::linalg::dot(&self.sketch[i * d..(i + 1) * d], x);
            if s >= 0.0 {
                words[i / 64] |= 1 << (i % 64);
            }
        }
        words
    }
}

impl KvCompressor for QjlCompressor {
    fn name(&self) -> String {
        "qjl".into()
    }

    fn compress(&self, block: &KvBlock, _obs: &[f32]) -> Box<dyn CompressedKv> {
        let d = block.d;
        assert_eq!(d, self.d, "QJL sketch built for dim {}", self.d);
        let n = block.n;
        let m = self.cfg.sketch_dim;
        let words_per_key = m.div_ceil(64);

        let mut key_bits = Vec::with_capacity(n * words_per_key);
        let mut key_norms = Vec::with_capacity(n);
        for t in 0..n {
            let k = block.key(t);
            key_bits.extend(self.sketch_signs(k));
            key_norms.push(f32_to_f16_bits(crate::math::linalg::norm2(k)));
        }

        // Values: 8-bit per-token asymmetric quantization.
        let levels = (1u32 << self.cfg.value_bits) - 1;
        let mut val_codes = vec![0u8; n * d];
        let mut val_zero = Vec::with_capacity(n);
        let mut val_scale = Vec::with_capacity(n);
        for t in 0..n {
            let row = block.value(t);
            let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let zero = quantize_f16(lo);
            let scale = quantize_f16(((hi - lo) / levels as f32).max(1e-8));
            val_zero.push(zero);
            val_scale.push(scale);
            for c in 0..d {
                val_codes[t * d + c] =
                    (((row[c] - zero) / scale).round().clamp(0.0, levels as f32)) as u8;
            }
        }

        Box::new(QjlKv {
            d,
            n,
            m,
            words_per_key,
            sketch: self.sketch.clone(),
            key_bits,
            key_norms,
            val_codes,
            val_zero,
            val_scale,
            tail: FpTail::new(d),
        })
    }

    fn target_ratio(&self) -> f64 {
        // keys: m bits + 16; values: 8·d + 32 — over 2·16·d.
        let d = self.d as f64;
        let m = self.cfg.sketch_dim as f64;
        ((m + 16.0) + (8.0 * d + 32.0)) / (32.0 * d)
    }
}

/// QJL store.
pub struct QjlKv {
    d: usize,
    n: usize,
    m: usize,
    words_per_key: usize,
    sketch: Vec<f32>,
    key_bits: Vec<u64>,
    key_norms: Vec<u16>,
    val_codes: Vec<u8>,
    val_zero: Vec<f32>,
    val_scale: Vec<f32>,
    tail: FpTail,
}

impl CompressedKv for QjlKv {
    fn n_tokens(&self) -> usize {
        self.n + self.tail.len()
    }

    fn positions(&self) -> Vec<u32> {
        let mut p: Vec<u32> = (0..self.n as u32).collect();
        p.extend_from_slice(&self.tail.positions);
        p
    }

    fn memory_bytes(&self) -> usize {
        self.key_bits.len() * 8
            + self.key_norms.len() * 2
            + self.val_codes.len()
            + (self.val_zero.len() + self.val_scale.len()) * 2
            + self.tail.memory_bytes()
        // The shared sketch is amortized across all layers/heads/tokens and
        // not charged per block (same convention as the QJL paper).
    }

    // analyze: allow(hot_path_alloc, "legacy per-sequence heap path: per-step query sketch words; the pool substrate is the serving default")
    fn key_scores(&self, q: &[f32], scores: &mut Vec<f32>) {
        scores.clear();
        // Sketch the query once, then per-key hamming distance.
        let m = self.m;
        let d = self.d;
        let qn = crate::math::linalg::norm2(q);
        let mut q_words = vec![0u64; self.words_per_key];
        for i in 0..m {
            let s = crate::math::linalg::dot(&self.sketch[i * d..(i + 1) * d], q);
            if s >= 0.0 {
                q_words[i / 64] |= 1 << (i % 64);
            }
        }
        // Mask for the final partial word.
        let tail_bits = m % 64;
        let last_mask = if tail_bits == 0 { u64::MAX } else { (1u64 << tail_bits) - 1 };
        for t in 0..self.n {
            let words = &self.key_bits[t * self.words_per_key..(t + 1) * self.words_per_key];
            let mut ham = 0u32;
            for (wi, (&a, &b)) in words.iter().zip(&q_words).enumerate() {
                let mut x = a ^ b;
                if wi + 1 == self.words_per_key {
                    x &= last_mask;
                }
                ham += x.count_ones();
            }
            let angle = std::f32::consts::PI * ham as f32 / m as f32;
            let kn = f16_bits_to_f32(self.key_norms[t]);
            scores.push(kn * qn * angle.cos());
        }
        self.tail.key_scores_into(q, scores);
    }

    fn value_combine(&self, weights: &[f32], out: &mut [f32]) {
        let d = self.d;
        for t in 0..self.n {
            let w = weights[t];
            if w == 0.0 {
                continue;
            }
            let zero = self.val_zero[t];
            let scale = self.val_scale[t];
            let row = &self.val_codes[t * d..(t + 1) * d];
            for c in 0..d {
                out[c] += w * (zero + scale * row[c] as f32);
            }
        }
        self.tail.value_combine(&weights[self.n..], out);
    }

    fn append(&mut self, position: u32, k: &[f32], v: &[f32]) {
        self.tail.append(position, k, v);
    }

    fn dim(&self) -> usize {
        self.d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block(n: usize, d: usize, seed: u64) -> KvBlock {
        let mut rng = Pcg64::new(seed);
        let mut k = vec![0.0f32; n * d];
        let mut v = vec![0.0f32; n * d];
        rng.fill_gaussian(&mut k);
        rng.fill_gaussian(&mut v);
        KvBlock::new(k, v, n, d)
    }

    #[test]
    fn identical_vectors_score_as_norm_product() {
        let d = 32;
        let mut b = block(2, d, 1);
        let mut rng = Pcg64::new(2);
        let mut q = vec![0.0f32; d];
        rng.fill_gaussian(&mut q);
        // Key 0 = q → hamming 0 → score = ‖k‖·‖q‖ = ‖q‖².
        b.keys[..d].copy_from_slice(&q);
        let kv = QjlCompressor::for_dim(d).compress(&b, &[]);
        let mut scores = Vec::new();
        kv.key_scores(&q, &mut scores);
        let want = crate::math::linalg::dot(&q, &q);
        assert!(
            (scores[0] - want).abs() / want < 0.05,
            "{} vs {}",
            scores[0],
            want
        );
    }

    #[test]
    fn orthogonal_vectors_score_near_zero() {
        let d = 32;
        let mut b = block(1, d, 3);
        for j in 0..d {
            b.keys[j] = if j == 0 { 5.0 } else { 0.0 };
        }
        let mut q = vec![0.0f32; d];
        q[1] = 5.0;
        let kv = QjlCompressor::for_dim(d).compress(&b, &[]);
        let mut scores = Vec::new();
        kv.key_scores(&q, &mut scores);
        // cos estimator noise ~ 1/√m; allow generous slack.
        assert!(scores[0].abs() < 8.0, "orthogonal score {}", scores[0]);
    }

    #[test]
    fn score_correlation_with_exact() {
        let d = 32;
        let n = 64;
        let b = block(n, d, 4);
        let kv = QjlCompressor::for_dim(d).compress(&b, &[]);
        let mut rng = Pcg64::new(5);
        let mut q = vec![0.0f32; d];
        rng.fill_gaussian(&mut q);
        let mut got = Vec::new();
        kv.key_scores(&q, &mut got);
        let want: Vec<f32> = (0..n).map(|t| crate::math::linalg::dot(b.key(t), &q)).collect();
        // Pearson correlation should be strong (1-bit sketch, m = 3d).
        let mw = want.iter().sum::<f32>() / n as f32;
        let mg = got.iter().sum::<f32>() / n as f32;
        let mut cov = 0.0;
        let mut vw = 0.0;
        let mut vg = 0.0;
        for t in 0..n {
            cov += (want[t] - mw) * (got[t] - mg);
            vw += (want[t] - mw).powi(2);
            vg += (got[t] - mg).powi(2);
        }
        let corr = cov / (vw.sqrt() * vg.sqrt());
        // 1-bit SimHash estimator at m = 3d has ~1/√m angle noise; 0.6 is
        // the right ballpark for d=32 Gaussian scores.
        assert!(corr > 0.6, "QJL score correlation {corr}");
    }

    #[test]
    fn values_8bit_accurate() {
        let d = 16;
        let n = 8;
        let b = block(n, d, 6);
        let kv = QjlCompressor::for_dim(d).compress(&b, &[]);
        let mut w = vec![0.0f32; n];
        w[3] = 1.0;
        let mut out = vec![0.0f32; d];
        kv.value_combine(&w, &mut out);
        let rel = crate::util::stats::rel_l2_error(&out, b.value(3));
        assert!(rel < 0.02, "8-bit value error {rel}");
    }

    #[test]
    fn memory_matches_target_ratio() {
        let d = 64;
        let n = 256;
        let b = block(n, d, 7);
        let comp = QjlCompressor::for_dim(d);
        let kv = comp.compress(&b, &[]);
        let ratio = kv.memory_bytes() as f64 / b.fp16_bytes() as f64;
        assert!((ratio - comp.target_ratio()).abs() < 0.05, "ratio {ratio}");
    }
}
