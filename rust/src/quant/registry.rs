//! Method registry: build any compression method by name, with the
//! paper's comparison settings (everything lined up at compression ratio
//! 0.25 for Fig. 3 / Table 1). Used by the CLI, eval harnesses and
//! benches so each experiment names methods as strings.

use crate::quant::compressor::KvCompressor;
use crate::quant::eviction::EvictionCompressor;
use crate::quant::exact::ExactCompressor;
use crate::quant::kivi::{KiviCompressor, KiviConfig};
use crate::quant::polar_kv::{PolarKvCompressor, PolarVariant};
use crate::quant::qjl::QjlCompressor;

/// Context a method may need (layer/head identity for PyramidKV/HeadKV).
#[derive(Clone, Copy, Debug)]
pub struct MethodContext {
    pub head_dim: usize,
    pub layer: usize,
    pub num_layers: usize,
    /// Head importance in [0,1] (HeadKV); eval computes it from retrieval
    /// scores, defaults to 0.5.
    pub head_importance: f64,
}

impl MethodContext {
    pub fn new(head_dim: usize) -> Self {
        Self { head_dim, layer: 0, num_layers: 1, head_importance: 0.5 }
    }

    pub fn at_layer(mut self, layer: usize, num_layers: usize) -> Self {
        self.layer = layer;
        self.num_layers = num_layers;
        self
    }
}

/// All method names in the paper's tables, in presentation order.
pub const TABLE1_METHODS: &[&str] = &[
    "exact",
    "snapkv",
    "headkv",
    "pyramidkv",
    "streamingllm",
    "kivi",
    "polarquant",
    "polarquant-r-offline",
    "polarquant-r-online",
];

/// Fig. 3 methods (paper compares these five at ratio 0.25).
pub const FIG3_METHODS: &[&str] =
    &["snapkv", "pyramidkv", "kivi", "polarquant", "polarquant-r-offline"];

/// Build a compressor by name. Ratio is the nominal compression target
/// for eviction methods (quantization methods' ratios are fixed by their
/// bit layouts — PolarQuant 0.242, KIVI ≈ 0.25 with its residual window).
pub fn build_method(name: &str, ratio: f64, ctx: MethodContext) -> Box<dyn KvCompressor> {
    let d = ctx.head_dim;
    match name {
        "exact" => Box::new(ExactCompressor),
        // The legacy heap cache stores fp16 either way; "fp16" exists as
        // a distinct name for the page substrate, where "exact" is f32.
        "fp16" => Box::new(ExactCompressor),
        "snapkv" => Box::new(EvictionCompressor::snapkv(ratio)),
        "pyramidkv" => Box::new(EvictionCompressor::pyramidkv(ratio, ctx.layer, ctx.num_layers)),
        "streamingllm" => Box::new(EvictionCompressor::streamingllm(ratio)),
        "headkv" => Box::new(EvictionCompressor::headkv(ratio, ctx.head_importance)),
        "kivi" => Box::new(KiviCompressor::new(KiviConfig::default())),
        "qjl" => Box::new(QjlCompressor::for_dim(d)),
        "polarquant" => Box::new(PolarKvCompressor::new(d, PolarVariant::plain())),
        "polarquant-r-offline" => {
            Box::new(PolarKvCompressor::new(d, PolarVariant::r_offline()))
        }
        "polarquant-r-online" => Box::new(PolarKvCompressor::new(d, PolarVariant::r_online())),
        other => panic!("unknown method {other:?}; known: {TABLE1_METHODS:?} + qjl"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::compressor::KvBlock;
    use crate::util::rng::{Pcg64, Rng};

    #[test]
    fn all_table1_methods_build_and_run() {
        let d = 32;
        let n = 64;
        let mut rng = Pcg64::new(1);
        let mut k = vec![0.0f32; n * d];
        let mut v = vec![0.0f32; n * d];
        rng.fill_gaussian(&mut k);
        rng.fill_gaussian(&mut v);
        let b = KvBlock::new(k, v, n, d);
        let mut q = vec![0.0f32; 2 * d];
        rng.fill_gaussian(&mut q);
        for name in TABLE1_METHODS.iter().chain(["qjl"].iter()) {
            let m = build_method(name, 0.25, MethodContext::new(d));
            assert_eq!(&m.name(), name);
            let kv = m.compress(&b, &q);
            assert!(kv.n_tokens() > 0, "{name}");
            assert!(kv.memory_bytes() > 0, "{name}");
            let mut scores = Vec::new();
            let mut qq = vec![0.0f32; d];
            rng.fill_gaussian(&mut qq);
            kv.key_scores(&qq, &mut scores);
            assert_eq!(scores.len(), kv.n_tokens(), "{name}");
            assert!(scores.iter().all(|s| s.is_finite()), "{name}");
        }
    }

    #[test]
    fn compressed_methods_use_quarter_memory() {
        let d = 64;
        let n = 512;
        let mut rng = Pcg64::new(2);
        let mut k = vec![0.0f32; n * d];
        let mut v = vec![0.0f32; n * d];
        rng.fill_gaussian(&mut k);
        rng.fill_gaussian(&mut v);
        let b = KvBlock::new(k, v, n, d);
        let mut q = vec![0.0f32; 8 * d];
        rng.fill_gaussian(&mut q);
        let exact = build_method("exact", 1.0, MethodContext::new(d)).compress(&b, &q);
        for name in &["snapkv", "streamingllm", "kivi", "polarquant-r-offline"] {
            let kv = build_method(name, 0.25, MethodContext::new(d)).compress(&b, &q);
            let ratio = kv.memory_bytes() as f64 / exact.memory_bytes() as f64;
            assert!(
                ratio > 0.1 && ratio < 0.4,
                "{name} should sit near ratio 0.25, got {ratio}"
            );
        }
    }

    #[test]
    #[should_panic]
    fn unknown_method_panics() {
        build_method("nope", 0.25, MethodContext::new(8));
    }
}
