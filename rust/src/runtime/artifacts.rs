//! Artifact manifest parsing (`artifacts/manifest.json`).
//!
//! The manifest is written by `python/compile/aot.py` and describes every
//! lowered graph (file, argument names/shapes/dtypes, output shapes), the
//! model config, the codec layout, and the default codebooks.

use crate::model::config::ModelConfig;
use crate::util::json::Json;
use crate::anyhow::{anyhow, bail, Context, Result};

/// One argument of a graph.
#[derive(Clone, Debug, PartialEq)]
pub struct ArgSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl ArgSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered graph.
#[derive(Clone, Debug)]
pub struct GraphSpec {
    pub name: String,
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

/// Codec layout recorded in the manifest.
#[derive(Clone, Debug)]
pub struct CodecSpec {
    pub head_dim: usize,
    pub levels: usize,
    pub level_bits: Vec<u8>,
    pub enc_n: usize,
    pub score_b: usize,
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: String,
    pub model: ModelConfig,
    pub codec: CodecSpec,
    pub graphs: Vec<GraphSpec>,
    pub weights_file: Option<String>,
    pub prefill_s: usize,
    pub decode_maxlen: usize,
    /// Default codebooks: (centroids, boundaries) per level.
    pub codebooks: Vec<(Vec<f32>, Vec<f32>)>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Self> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("read {path}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parse {path}: {e}"))?;

        let model = j.get("model").ok_or_else(|| anyhow!("missing model"))?;
        let num = |node: &Json, k: &str| -> Result<usize> {
            node.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("missing field {k}"))
        };
        let model_cfg = ModelConfig {
            vocab: num(model, "vocab")?,
            d_model: num(model, "d_model")?,
            n_layers: num(model, "n_layers")?,
            n_heads: num(model, "n_heads")?,
            head_dim: num(model, "head_dim")?,
            d_ff: num(model, "d_ff")?,
            rope_theta: model.get("rope_theta").and_then(|v| v.as_f64()).unwrap_or(1e4) as f32,
            rms_eps: model.get("rms_eps").and_then(|v| v.as_f64()).unwrap_or(1e-5) as f32,
        };

        let codec = j.get("codec").ok_or_else(|| anyhow!("missing codec"))?;
        let codec_spec = CodecSpec {
            head_dim: num(codec, "head_dim")?,
            levels: num(codec, "levels")?,
            level_bits: codec
                .get("level_bits")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("level_bits"))?
                .iter()
                .filter_map(|x| x.as_f64())
                .map(|x| x as u8)
                .collect(),
            enc_n: num(codec, "enc_n")?,
            score_b: num(codec, "score_b")?,
        };

        let parse_specs = |node: &Json| -> Result<Vec<ArgSpec>> {
            node.as_arr()
                .ok_or_else(|| anyhow!("expected array"))?
                .iter()
                .map(|a| {
                    Ok(ArgSpec {
                        name: a
                            .get("name")
                            .and_then(|v| v.as_str())
                            .unwrap_or("out")
                            .to_string(),
                        shape: a
                            .get("shape")
                            .and_then(|v| v.as_arr())
                            .ok_or_else(|| anyhow!("shape"))?
                            .iter()
                            .filter_map(|x| x.as_usize())
                            .collect(),
                        dtype: a
                            .get("dtype")
                            .and_then(|v| v.as_str())
                            .unwrap_or("float32")
                            .to_string(),
                    })
                })
                .collect()
        };

        let graphs_node = j.get("graphs").ok_or_else(|| anyhow!("missing graphs"))?;
        let mut graphs = Vec::new();
        if let Json::Obj(m) = graphs_node {
            for (name, g) in m {
                graphs.push(GraphSpec {
                    name: name.clone(),
                    file: g
                        .get("file")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| anyhow!("graph file"))?
                        .to_string(),
                    args: parse_specs(g.get("args").ok_or_else(|| anyhow!("args"))?)?,
                    outputs: parse_specs(g.get("outputs").ok_or_else(|| anyhow!("outputs"))?)?,
                });
            }
        } else {
            bail!("graphs must be an object");
        }

        let shapes = j.get("shapes").ok_or_else(|| anyhow!("missing shapes"))?;

        // Codebooks.
        let mut codebooks = Vec::new();
        if let Some(Json::Obj(books)) = j.get("codebooks") {
            for l in 1..=codec_spec.levels {
                let b = books
                    .get(&format!("level{l}"))
                    .ok_or_else(|| anyhow!("codebook level{l}"))?;
                let cent = b
                    .get("centroids")
                    .and_then(|v| v.as_f32_vec())
                    .ok_or_else(|| anyhow!("centroids"))?;
                let bnd = b
                    .get("boundaries")
                    .and_then(|v| v.as_f32_vec())
                    .ok_or_else(|| anyhow!("boundaries"))?;
                codebooks.push((cent, bnd));
            }
        }

        Ok(Manifest {
            dir: dir.to_string(),
            model: model_cfg,
            codec: codec_spec,
            graphs,
            weights_file: j
                .get("weights_file")
                .and_then(|v| v.as_str())
                .map(|s| s.to_string()),
            prefill_s: num(shapes, "prefill_s")?,
            decode_maxlen: num(shapes, "decode_maxlen")?,
            codebooks,
        })
    }

    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs
            .iter()
            .find(|g| g.name == name)
            .ok_or_else(|| anyhow!("graph {name} not in manifest"))
    }

    pub fn hlo_path(&self, name: &str) -> Result<String> {
        Ok(format!("{}/{}", self.dir, self.graph(name)?.file))
    }

    /// Default artifacts directory (env override → ./artifacts).
    pub fn default_dir() -> String {
        std::env::var("POLARQUANT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
    }

    pub fn available(dir: &str) -> bool {
        std::path::Path::new(&format!("{dir}/manifest.json")).exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text/1",
      "model": {"vocab": 64, "d_model": 32, "n_layers": 2, "n_heads": 2,
                 "head_dim": 16, "d_ff": 48, "rope_theta": 10000.0,
                 "rms_eps": 1e-5, "params_order": []},
      "codec": {"head_dim": 64, "levels": 4, "level_bits": [4,2,2,2],
                 "enc_n": 256, "score_b": 4},
      "shapes": {"prefill_s": 128, "decode_maxlen": 512},
      "graphs": {"g1": {"file": "g1.hlo.txt",
                          "args": [{"name": "x", "shape": [2,3], "dtype": "float32"}],
                          "outputs": [{"shape": [2], "dtype": "float32"}]}},
      "codebooks": {
        "level1": {"bits": 1, "centroids": [0.5, 1.5], "boundaries": [1.0]},
        "level2": {"bits": 1, "centroids": [0.3, 0.9], "boundaries": [0.6]},
        "level3": {"bits": 1, "centroids": [0.3, 0.9], "boundaries": [0.6]},
        "level4": {"bits": 1, "centroids": [0.3, 0.9], "boundaries": [0.6]}
      },
      "weights_file": "w.bin"
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let dir = std::env::temp_dir().join("pq_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let m = Manifest::load(dir.to_str().unwrap()).unwrap();
        assert_eq!(m.model.vocab, 64);
        assert_eq!(m.codec.level_bits, vec![4, 2, 2, 2]);
        assert_eq!(m.graphs.len(), 1);
        let g = m.graph("g1").unwrap();
        assert_eq!(g.args[0].shape, vec![2, 3]);
        assert_eq!(g.args[0].elements(), 6);
        assert_eq!(m.weights_file.as_deref(), Some("w.bin"));
        assert_eq!(m.codebooks.len(), 4);
        assert!(m.graph("nope").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_fields_error() {
        let dir = std::env::temp_dir().join("pq_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"graphs": {}}"#).unwrap();
        assert!(Manifest::load(dir.to_str().unwrap()).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
