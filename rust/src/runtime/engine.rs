//! PJRT execution engine: compile HLO-text artifacts once, execute many.
//!
//! Wraps the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `client.compile` → `execute`). Executables are
//! compiled lazily per graph and cached; inputs/outputs are `xla::Literal`s
//! with f32/i32 payloads per the manifest conventions.

use crate::runtime::artifacts::Manifest;
use crate::anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Lazily-compiled artifact executor.
pub struct PjrtEngine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<BTreeMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtEngine {
    pub fn new(artifacts_dir: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Self { manifest, client, cache: Mutex::new(BTreeMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for a graph.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = self.manifest.hlo_path(name)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse HLO text {path}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute a graph with literal inputs; returns the flattened tuple
    /// outputs (aot.py lowers everything with return_tuple=True).
    pub fn run(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = args.iter().collect();
        self.run_borrowed(name, &refs)
    }

    /// Like [`Self::run`] but borrowing the argument literals — the model
    /// runtime keeps weights as cached literals and passes references, so
    /// nothing is copied per step.
    pub fn run_borrowed(&self, name: &str, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self.manifest.graph(name)?;
        if spec.args.len() != args.len() {
            return Err(anyhow!(
                "graph {name} expects {} args, got {}",
                spec.args.len(),
                args.len()
            ));
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow!("execute {name}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {name}: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e}"))
    }
}

/// Build an f32 literal of the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    crate::anyhow::ensure!(n == data.len(), "shape {shape:?} vs {} elements", data.len());
    let dims: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    crate::anyhow::ensure!(n == data.len(), "shape {shape:?} vs {} elements", data.len());
    let dims: Vec<i64> = shape.iter().map(|&s| s as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// Scalar i32 literal.
pub fn lit_i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 vector from a literal.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))
}

/// Extract an i32 vector from a literal.
pub fn to_i32_vec(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))
}

// NOTE: engine integration tests live in rust/tests/artifacts_parity.rs
// (they need `make artifacts` to have run; unit tests here would drag the
// PJRT runtime into every `cargo test --lib` invocation).
