//! The PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! lowered once by `python/compile/aot.py`) and executes them through the
//! PJRT C API via the `xla` crate. Python never runs at request time.

pub mod artifacts;
// The PJRT execution layer needs the external `xla` crate, which is not
// available in the offline build. It is feature-gated behind `pjrt` (a
// marker feature with no dependencies of its own) so the manifest loader
// above — pure Rust, no xla types — stays in the default build while the
// engine compiles only where a vendored xla crate is present.
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod model_runtime;
