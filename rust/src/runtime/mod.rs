//! The PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`,
//! lowered once by `python/compile/aot.py`) and executes them through the
//! PJRT C API via the `xla` crate. Python never runs at request time.

pub mod artifacts;
pub mod engine;
pub mod model_runtime;
