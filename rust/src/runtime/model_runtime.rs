//! Model execution through the PJRT artifacts (the "pjrt" engine mode).
//!
//! Wraps `model_prefill` / `model_decode_step` graphs: weights are
//! converted to literals once, prompts are chunk-padded to the lowered
//! prefill length, and the decode step runs against fixed-size f32 cache
//! buffers owned on the Rust side.
//!
//! The *quantized* serving hot path stays native (rust codec); this engine
//! exists to (a) prove the three-layer AOT contract end-to-end and
//! (b) cross-validate the native model (logit parity tests).

use crate::model::config::ModelConfig;
use crate::model::weights::Weights;
use crate::runtime::engine::{lit_f32, lit_i32, lit_i32_scalar, to_f32_vec, PjrtEngine};
use crate::anyhow::{ensure, Result};

/// PJRT-backed model session.
pub struct PjrtModel<'e> {
    pub engine: &'e PjrtEngine,
    pub cfg: ModelConfig,
    /// Weight literals in canonical order (shared across calls).
    weight_lits: Vec<xla::Literal>,
    maxlen: usize,
    prefill_s: usize,
}

/// Decode-time cache buffers (L, MAXLEN, H, Dh) flattened.
pub struct PjrtKvState {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: usize,
    cfg: ModelConfig,
    maxlen: usize,
}

impl PjrtKvState {
    fn row(&self, l: usize, pos: usize) -> std::ops::Range<usize> {
        let (h, dh) = (self.cfg.n_heads, self.cfg.head_dim);
        let base = (l * self.maxlen + pos) * h * dh;
        base..base + h * dh
    }

    /// Write one token's (k, v) rows (L × H × Dh each) at `pos`.
    pub fn write(&mut self, pos: usize, new_k: &[f32], new_v: &[f32]) {
        let (h, dh) = (self.cfg.n_heads, self.cfg.head_dim);
        for l in 0..self.cfg.n_layers {
            let r = self.row(l, pos);
            self.k[r.clone()].copy_from_slice(&new_k[l * h * dh..(l + 1) * h * dh]);
            self.v[r].copy_from_slice(&new_v[l * h * dh..(l + 1) * h * dh]);
        }
        self.len = self.len.max(pos + 1);
    }
}

impl<'e> PjrtModel<'e> {
    pub fn new(engine: &'e PjrtEngine, weights: &Weights) -> Result<Self> {
        let cfg = weights.cfg.clone();
        ensure!(
            cfg == engine.manifest.model,
            "weights config does not match the lowered model graphs"
        );
        let mut weight_lits = Vec::new();
        for (name, data) in weights.flat_order() {
            let shape = cfg.param_shape(name);
            weight_lits.push(lit_f32(data, &shape)?);
        }
        Ok(Self {
            engine,
            cfg,
            weight_lits,
            maxlen: engine.manifest.decode_maxlen,
            prefill_s: engine.manifest.prefill_s,
        })
    }

    pub fn maxlen(&self) -> usize {
        self.maxlen
    }

    pub fn fresh_kv(&self) -> PjrtKvState {
        let n = self.cfg.n_layers * self.maxlen * self.cfg.n_heads * self.cfg.head_dim;
        PjrtKvState {
            k: vec![0.0; n],
            v: vec![0.0; n],
            len: 0,
            cfg: self.cfg.clone(),
            maxlen: self.maxlen,
        }
    }

    /// Run the prefill graph on `tokens` (≤ the lowered chunk size; padded
    /// with token 0 — caller slices logits by true length). Returns
    /// (logits S×V, k, v) with k/v shaped (L, S, H, Dh) flattened.
    pub fn prefill_chunk(&self, tokens: &[u32]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let s = self.prefill_s;
        ensure!(
            tokens.len() <= s,
            "prompt chunk {} exceeds lowered prefill length {s}",
            tokens.len()
        );
        let mut padded = vec![0i32; s];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let toks = lit_i32(&padded, &[s])?;
        // Borrow cached weight literals — no copies on the call path.
        let mut args: Vec<&xla::Literal> = vec![&toks];
        args.extend(self.weight_lits.iter());
        let out = self.engine.run_borrowed("model_prefill", &args)?;
        ensure!(out.len() == 3, "prefill returns 3 outputs");
        Ok((to_f32_vec(&out[0])?, to_f32_vec(&out[1])?, to_f32_vec(&out[2])?))
    }

    /// Run one decode step at `pos` against the cache buffers; writes the
    /// new K/V rows into `kv` and returns the logits.
    pub fn decode_step(&self, token: u32, pos: usize, kv: &mut PjrtKvState) -> Result<Vec<f32>> {
        ensure!(pos < self.maxlen, "pos {pos} exceeds decode maxlen {}", self.maxlen);
        let shape = [
            self.cfg.n_layers,
            self.maxlen,
            self.cfg.n_heads,
            self.cfg.head_dim,
        ];
        let tok = lit_i32_scalar(token as i32);
        let p = lit_i32_scalar(pos as i32);
        let kbuf = lit_f32(&kv.k, &shape)?;
        let vbuf = lit_f32(&kv.v, &shape)?;
        let mut args: Vec<&xla::Literal> = vec![&tok, &p, &kbuf, &vbuf];
        args.extend(self.weight_lits.iter());
        let out = self.engine.run_borrowed("model_decode_step", &args)?;
        ensure!(out.len() == 3, "decode returns 3 outputs");
        let logits = to_f32_vec(&out[0])?;
        let new_k = to_f32_vec(&out[1])?;
        let new_v = to_f32_vec(&out[2])?;
        kv.write(pos, &new_k, &new_v);
        Ok(logits)
    }
}

// Integration coverage: rust/tests/artifacts_parity.rs (needs artifacts).
