//! Tiny CLI argument parser (no `clap` offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args,
//! with typed getters and a generated usage string. Each binary declares
//! its options up-front so `--help` output stays accurate.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Declarative arg parser: register options, then `parse`.
#[derive(Debug, Default)]
pub struct Args {
    pub program: String,
    pub about: &'static str,
    specs: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn new(about: &'static str) -> Self {
        Self { about, ..Default::default() }
    }

    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: Some(default), is_flag: false });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}\n\nUSAGE: {} [OPTIONS]\n\nOPTIONS:", self.about, self.program);
        for spec in &self.specs {
            let d = match (spec.is_flag, spec.default) {
                (true, _) => String::new(),
                (false, Some(d)) => format!(" [default: {d}]"),
                (false, None) => " (required)".to_string(),
            };
            let _ = writeln!(s, "  --{:<24} {}{}", spec.name, spec.help, d);
        }
        s
    }

    /// Parse from an iterator (first item must be argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(mut self, argv: I) -> Result<Self, String> {
        let mut it = argv.into_iter();
        self.program = it.next().unwrap_or_else(|| "prog".into());
        let known_flag = |specs: &[OptSpec], n: &str| {
            specs.iter().find(|s| s.name == n).map(|s| s.is_flag)
        };
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                match known_flag(&self.specs, &name) {
                    Some(true) => {
                        self.flags.insert(name, true);
                    }
                    Some(false) => {
                        let v = match inline {
                            Some(v) => v,
                            None => it
                                .next()
                                .ok_or_else(|| format!("missing value for --{name}"))?,
                        };
                        self.values.insert(name, v);
                    }
                    None => return Err(format!("unknown option --{name}\n\n{}", self.usage())),
                }
            } else {
                self.positional.push(a);
            }
        }
        // Check required options.
        for spec in &self.specs {
            if !spec.is_flag
                && spec.default.is_none()
                && !self.values.contains_key(spec.name)
            {
                return Err(format!("missing required option --{}\n\n{}", spec.name, self.usage()));
            }
        }
        Ok(self)
    }

    pub fn parse(self) -> Self {
        let argv: Vec<String> = std::env::args().collect();
        match self.parse_from(argv) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }

    fn raw(&self, name: &str) -> Option<String> {
        if let Some(v) = self.values.get(name) {
            return Some(v.clone());
        }
        self.specs
            .iter()
            .find(|s| s.name == name && !s.is_flag)
            .and_then(|s| s.default.map(|d| d.to_string()))
    }

    pub fn get(&self, name: &str) -> String {
        self.raw(name).unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        let v = self.get(name);
        v.parse().unwrap_or_else(|_| panic!("--{name}: expected integer, got {v:?}"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        let v = self.get(name);
        v.parse().unwrap_or_else(|_| panic!("--{name}: expected integer, got {v:?}"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        let v = self.get(name);
        v.parse().unwrap_or_else(|_| panic!("--{name}: expected float, got {v:?}"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::new("t")
            .opt("n", "4", "count")
            .opt("name", "x", "name")
            .flag("verbose", "talk")
            .parse_from(argv(&["prog", "--n", "8", "--name=abc", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("n"), 8);
        assert_eq!(a.get("name"), "abc");
        assert!(a.get_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::new("t")
            .opt("n", "4", "count")
            .flag("v", "")
            .parse_from(argv(&["prog"]))
            .unwrap();
        assert_eq!(a.get_usize("n"), 4);
        assert!(!a.get_flag("v"));
    }

    #[test]
    fn required_missing_errors() {
        let r = Args::new("t").req("model", "path").parse_from(argv(&["prog"]));
        assert!(r.is_err());
    }

    #[test]
    fn unknown_option_errors() {
        let r = Args::new("t").parse_from(argv(&["prog", "--bogus"]));
        assert!(r.is_err());
    }

    #[test]
    fn help_is_error_with_usage() {
        let r = Args::new("about-text").opt("n", "1", "").parse_from(argv(&["prog", "--help"]));
        let msg = r.unwrap_err();
        assert!(msg.contains("about-text"));
        assert!(msg.contains("--n"));
    }
}
