//! Shared non-cryptographic hashing: FNV-1a, the one hash the serving
//! stack uses for both session affinity and prefix-directory
//! fingerprints. One implementation so the two can never drift.

pub const FNV1A_SEED: u64 = 0xcbf29ce484222325;
const FNV1A_PRIME: u64 = 0x100000001b3;

/// Fold `bytes` into FNV-1a state `h` (start from [`FNV1A_SEED`]).
/// Returning the state makes the hash rollable: feeding chunks one at a
/// time yields a chain where each intermediate state commits to the
/// whole byte stream so far.
pub fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for b in bytes {
        h ^= *b as u64;
        h = h.wrapping_mul(FNV1A_PRIME);
    }
    h
}

/// One-shot FNV-1a of a string (session-affinity hashing).
pub fn fnv1a_str(s: &str) -> u64 {
    fnv1a(FNV1A_SEED, s.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_known_fnv1a_vectors() {
        // Reference values of the standard 64-bit FNV-1a.
        assert_eq!(fnv1a_str(""), 0xcbf29ce484222325);
        assert_eq!(fnv1a_str("a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a_str("foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn rolling_equals_one_shot() {
        let whole = fnv1a(FNV1A_SEED, b"polar quant");
        let rolled = fnv1a(fnv1a(FNV1A_SEED, b"polar "), b"quant");
        assert_eq!(whole, rolled);
    }
}
