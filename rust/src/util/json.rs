//! Minimal JSON codec (no serde available offline).
//!
//! Supports the full JSON value model; used for the artifact manifest,
//! experiment reports, the TCP serving protocol and config files. The
//! parser is a straightforward recursive-descent over bytes with proper
//! string escapes and number handling; the encoder is canonical enough for
//! round-tripping our own documents.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a BTreeMap for deterministic
/// encoding (stable diffs in golden tests).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- constructors ------------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|&s| Json::str(s)).collect())
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn set(&mut self, key: &str, v: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v);
        } else {
            panic!("Json::set on non-object");
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|x| x.as_f64()).map(|x| x as f32).collect())
    }

    /// Lookup `a.b.c` style dotted paths.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- encoding ----------------------------------------------------------
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    pub fn encode_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(*x, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    out.push_str(&"  ".repeat(indent + 1));
                    x.write_pretty(out, indent + 1);
                    if i + 1 < v.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    out.push_str(&"  ".repeat(indent + 1));
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < m.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        // JSON has no inf/nan; encode as null (documented lossy behaviour).
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not needed for our docs);
                            // map lone surrogates to replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "1e-3"] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.encode()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn roundtrip_nested() {
        let s = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = Json::parse(s).unwrap();
        let v2 = Json::parse(&v.encode()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,", "{\"a\"}", "tru", "1.2.3", "[1] x"] {
            assert!(Json::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""héllo A\t""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo A\t");
        let enc = Json::str("a\"b\\c\nd").encode();
        assert_eq!(Json::parse(&enc).unwrap().as_str().unwrap(), "a\"b\\c\nd");
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::from_pairs(vec![
            ("name", Json::str("polarquant")),
            ("dims", Json::arr_f64(&[1.0, 2.0, 3.0])),
            ("nested", Json::from_pairs(vec![("x", Json::Bool(true))])),
        ]);
        let v2 = Json::parse(&v.encode_pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn numbers_integral_encoding() {
        assert_eq!(Json::Num(5.0).encode(), "5");
        assert_eq!(Json::Num(5.5).encode(), "5.5");
        assert_eq!(Json::Num(f64::NAN).encode(), "null");
    }

    #[test]
    fn dotted_path() {
        let v = Json::parse(r#"{"a":{"b":{"c":7}}}"#).unwrap();
        assert_eq!(v.path("a.b.c").unwrap().as_f64().unwrap(), 7.0);
        assert!(v.path("a.x").is_none());
    }
}
