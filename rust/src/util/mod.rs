//! Small self-contained utility substrates (no external deps available in
//! this build environment beyond `xla`/`anyhow`, so RNG, JSON, CLI parsing,
//! stats, timing and thread pools are implemented from scratch here).

pub mod args;
pub mod hash;
pub mod json;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threadpool;
pub mod timer;
