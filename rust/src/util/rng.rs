//! Deterministic pseudo-random number generation.
//!
//! The build environment has no `rand` crate, so we implement a small,
//! well-tested PRNG stack from scratch:
//!
//! * [`SplitMix64`] — seeding / stream-splitting generator.
//! * [`Pcg64`] — the main generator (PCG XSL RR 128/64), good statistical
//!   quality and a tiny state. Used everywhere randomness is needed:
//!   synthetic weights, workload generation, preconditioners, k-means++.
//!
//! All sampling helpers (uniform, Gaussian via Box–Muller, shuffling,
//! weighted choice) live on the [`Rng`] trait so tests can substitute a
//! counting generator.

/// Minimal RNG interface: a source of uniform `u64`s plus derived samplers.
pub trait Rng {
    /// Next uniformly distributed 64-bit value.
    fn next_u64(&mut self) -> u64;

    /// Uniform in `[0, 1)` with 53 bits of mantissa entropy.
    fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits — unbiased and exactly representable.
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as `f32`.
    fn next_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (n > 0) using Lemire's rejection method.
    fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        // Widening multiply rejection sampling; bias-free.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (cached second value is *not* kept to
    /// stay allocation- and state-free; cost is fine for our workloads).
    fn gaussian(&mut self) -> f64 {
        // Avoid log(0).
        let u1 = loop {
            let u = self.next_f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Standard normal as f32.
    fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Fill a slice with i.i.d. standard normals.
    fn fill_gaussian(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.gaussian_f32();
        }
    }

    /// Fill a slice with uniforms in `[lo, hi)`.
    fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = lo + (hi - lo) * self.next_f32();
        }
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index proportionally to the non-negative weights.
    /// Returns `None` if all weights are zero/empty.
    fn weighted_choice(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) {
            return None;
        }
        let mut t = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return Some(i);
            }
        }
        Some(weights.len() - 1)
    }

    /// Sample from Exp(rate) — used by the workload arrival generator.
    fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }
}

/// SplitMix64: tiny generator mainly used to expand a seed into streams.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }
}

impl Rng for SplitMix64 {
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG XSL RR 128/64 — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MUL: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

impl Pcg64 {
    /// Construct from a 64-bit seed; state and stream are both derived via
    /// SplitMix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = (((sm.next_u64() as u128) << 64) | sm.next_u64() as u128) | 1;
        let mut g = Self { state, inc };
        g.next_u64(); // burn-in one step so state is well mixed
        g
    }

    /// Derive an independent child stream (used to give each worker thread /
    /// layer / head its own generator deterministically).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        Pcg64::new(s)
    }
}

impl Rng for Pcg64 {
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MUL).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_deterministic_and_seed_sensitive() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(1);
        let mut c = Pcg64::new(2);
        let va: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut g = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut g = Pcg64::new(9);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let x = g.next_below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn gaussian_moments() {
        let mut g = Pcg64::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = g.gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Pcg64::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        g.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_choice_respects_zeros() {
        let mut g = Pcg64::new(5);
        for _ in 0..1000 {
            let i = g.weighted_choice(&[0.0, 1.0, 0.0]).unwrap();
            assert_eq!(i, 1);
        }
        assert!(g.weighted_choice(&[0.0, 0.0]).is_none());
        assert!(g.weighted_choice(&[]).is_none());
    }

    #[test]
    fn split_streams_differ() {
        let mut g = Pcg64::new(13);
        let mut a = g.split(0);
        let mut b = g.split(1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
