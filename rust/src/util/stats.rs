//! Summary statistics, histograms and latency percentile tracking used by
//! the evaluation harnesses (Fig. 2 histograms, Table 2 wall-clock, serving
//! metrics) and by the hand-rolled bench runner.

/// Running mean/variance via Welford's algorithm plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-range histogram with uniform bins; `add` clamps to the range so
/// outliers land in the edge bins (documented — Fig. 2 uses known ranges).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self { lo, hi, counts: vec![0; bins], total: 0 }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1) as usize;
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Normalized densities (integrate to 1 over [lo, hi]).
    pub fn density(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        let n = self.total.max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / (n * w)).collect()
    }

    pub fn bin_centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len()).map(|i| self.lo + (i as f64 + 0.5) * w).collect()
    }

    /// Render a one-line unicode sparkline (for terminal figures).
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.counts.iter().copied().max().unwrap_or(0).max(1) as f64;
        self.counts
            .iter()
            .map(|&c| BARS[((c as f64 / max) * 7.0).round() as usize])
            .collect()
    }
}

/// Percentile estimation over a stored sample (exact, sorts on query).
/// Serving latencies are small enough (≤ millions) that exact is fine.
#[derive(Clone, Debug, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
}

impl Percentiles {
    pub fn new() -> Self {
        Self { xs: Vec::new() }
    }

    pub fn add(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn pct(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        let mut v = self.xs.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (v.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            let t = rank - lo as f64;
            v[lo] * (1.0 - t) + v[hi] * t
        }
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.xs.iter().sum::<f64>() / self.xs.len() as f64
    }
}

/// Mean of a slice (empty → NaN).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Relative L2 error ‖a-b‖/‖b‖ (b is reference). Zero reference → absolute.
pub fn rel_l2_error(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (y as f64).powi(2);
    }
    if den == 0.0 {
        num.sqrt()
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        s.extend(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 1.25).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn histogram_density_integrates_to_one() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        for i in 0..1000 {
            h.add((i as f64 + 0.5) / 1000.0);
        }
        let w = 0.1;
        let total: f64 = h.density().iter().map(|d| d * w).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_clamps_outliers() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.add(-5.0);
        h.add(5.0);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[3], 1);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            p.add(x);
        }
        assert!((p.pct(0.0) - 1.0).abs() < 1e-12);
        assert!((p.pct(50.0) - 3.0).abs() < 1e-12);
        assert!((p.pct(100.0) - 5.0).abs() < 1e-12);
        assert!((p.pct(25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rel_error_zero_on_equal() {
        let a = [1.0f32, -2.0, 3.0];
        assert_eq!(rel_l2_error(&a, &a), 0.0);
    }
}
