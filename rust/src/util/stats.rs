//! Summary statistics, histograms and latency percentile tracking used by
//! the evaluation harnesses (Fig. 2 histograms, Table 2 wall-clock, serving
//! metrics) and by the hand-rolled bench runner.

use crate::util::rng::{Pcg64, Rng};

/// Running mean/variance via Welford's algorithm plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn var(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-range histogram with uniform bins; `add` clamps to the range so
/// outliers land in the edge bins (documented — Fig. 2 uses known ranges).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self { lo, hi, counts: vec![0; bins], total: 0 }
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64) as isize).clamp(0, bins as isize - 1) as usize;
        self.counts[idx] += 1;
        self.total += 1;
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Normalized densities (integrate to 1 over [lo, hi]).
    pub fn density(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        let n = self.total.max(1) as f64;
        self.counts.iter().map(|&c| c as f64 / (n * w)).collect()
    }

    pub fn bin_centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len()).map(|i| self.lo + (i as f64 + 0.5) * w).collect()
    }

    /// Render a one-line unicode sparkline (for terminal figures).
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.counts.iter().copied().max().unwrap_or(0).max(1) as f64;
        self.counts
            .iter()
            .map(|&c| BARS[((c as f64 / max) * 7.0).round() as usize])
            .collect()
    }
}

/// Default reservoir capacity: exact below this count, a uniform sample
/// above it. 4096 points bound the p99 estimator error well under 1% on
/// million-sample streams (pinned by `reservoir_percentiles_track_exact`)
/// while keeping a long-lived server's latency state at a fixed ~32 KiB.
pub const RESERVOIR_CAP: usize = 4096;

/// Percentile estimation over a bounded reservoir sample (Vitter's
/// Algorithm R, deterministic via [`Pcg64`]). Exact while fewer than `cap`
/// samples have been seen; an unbiased uniform subsample afterwards, so a
/// serving process can record latencies forever in O(cap) memory. The
/// sorted order is cached and invalidated on `add`, so repeated `pct`
/// queries (one per percentile per snapshot) sort at most once.
#[derive(Clone, Debug)]
pub struct Percentiles {
    xs: Vec<f64>,
    cap: usize,
    seen: u64,
    sum: f64,
    sorted: bool,
    rng: Pcg64,
}

impl Default for Percentiles {
    fn default() -> Self {
        Self::new()
    }
}

impl Percentiles {
    pub fn new() -> Self {
        Self::with_capacity(RESERVOIR_CAP)
    }

    /// Reservoir bounded at `cap` stored samples (cap > 0). The RNG seed is
    /// fixed: estimates are a pure function of the input stream.
    pub fn with_capacity(cap: usize) -> Self {
        assert!(cap > 0, "Percentiles::with_capacity(0)");
        Self { xs: Vec::new(), cap, seen: 0, sum: 0.0, sorted: false, rng: Pcg64::new(0x9c11) }
    }

    pub fn add(&mut self, x: f64) {
        self.seen += 1;
        self.sum += x;
        if self.xs.len() < self.cap {
            self.xs.push(x);
        } else {
            // Algorithm R: keep the n-th sample with probability cap/n.
            let j = self.rng.next_below(self.seen) as usize;
            if j < self.cap {
                self.xs[j] = x;
            } else {
                return; // reservoir untouched — sort cache stays valid
            }
        }
        self.sorted = false;
    }

    /// Total samples observed (not the stored reservoir size).
    pub fn len(&self) -> usize {
        self.seen as usize
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Linear-interpolated percentile, p in [0, 100]. Exact until `cap`
    /// samples have been seen, a reservoir estimate afterwards.
    pub fn pct(&mut self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let v = &self.xs;
        let rank = (p / 100.0) * (v.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            v[lo]
        } else {
            let t = rank - lo as f64;
            v[lo] * (1.0 - t) + v[hi] * t
        }
    }

    /// Exact mean over every sample ever added (running sum, not the
    /// reservoir subsample).
    pub fn mean(&self) -> f64 {
        if self.seen == 0 {
            return f64::NAN;
        }
        self.sum / self.seen as f64
    }
}

/// Mean of a slice (empty → NaN).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        f64::NAN
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Relative L2 error ‖a-b‖/‖b‖ (b is reference). Zero reference → absolute.
pub fn rel_l2_error(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.iter().zip(b) {
        num += ((x - y) as f64).powi(2);
        den += (y as f64).powi(2);
    }
    if den == 0.0 {
        num.sqrt()
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        s.extend(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 1.25).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn histogram_density_integrates_to_one() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        for i in 0..1000 {
            h.add((i as f64 + 0.5) / 1000.0);
        }
        let w = 0.1;
        let total: f64 = h.density().iter().map(|d| d * w).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_clamps_outliers() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.add(-5.0);
        h.add(5.0);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[3], 1);
    }

    #[test]
    fn percentiles_interpolate() {
        let mut p = Percentiles::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            p.add(x);
        }
        assert!((p.pct(0.0) - 1.0).abs() < 1e-12);
        assert!((p.pct(50.0) - 3.0).abs() < 1e-12);
        assert!((p.pct(100.0) - 5.0).abs() < 1e-12);
        assert!((p.pct(25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn reservoir_percentiles_track_exact() {
        // 1M lognormal-ish samples (latency-shaped: heavy right tail).
        // The bounded reservoir must agree with the exact empirical
        // percentiles to well under the tail spread, and the mean must be
        // exact (running sum, not subsampled). Fully deterministic: fixed
        // input seed, fixed reservoir seed.
        let mut rng = Pcg64::new(42);
        let mut est = Percentiles::new();
        let mut exact: Vec<f64> = Vec::with_capacity(1_000_000);
        let mut sum = 0.0f64;
        for _ in 0..1_000_000 {
            let x = (0.5 * rng.gaussian()).exp();
            est.add(x);
            exact.push(x);
            sum += x;
        }
        assert_eq!(est.len(), 1_000_000);
        exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [50.0, 90.0, 99.0] {
            let rank = (p / 100.0) * (exact.len() - 1) as f64;
            let truth = exact[rank.round() as usize];
            let got = est.pct(p);
            let rel = (got - truth).abs() / truth;
            assert!(rel < 0.05, "p{p}: reservoir {got} vs exact {truth} (rel {rel})");
        }
        assert!((est.mean() - sum / 1e6).abs() < 1e-9, "mean must be exact");
    }

    #[test]
    fn reservoir_exact_below_capacity_and_bounded_above() {
        let mut p = Percentiles::with_capacity(8);
        for x in 0..6 {
            p.add(x as f64);
        }
        // Below cap: exact, including after interleaved queries (cache
        // invalidation on add).
        assert!((p.pct(100.0) - 5.0).abs() < 1e-12);
        p.add(9.0);
        assert!((p.pct(100.0) - 9.0).abs() < 1e-12);
        for x in 0..10_000 {
            p.add(x as f64);
        }
        assert_eq!(p.len(), 10_007);
        assert!(p.pct(50.0).is_finite());
    }

    #[test]
    fn rel_error_zero_on_equal() {
        let a = [1.0f32, -2.0, 3.0];
        assert_eq!(rel_l2_error(&a, &a), 0.0);
    }
}
