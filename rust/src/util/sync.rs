//! Lock helpers for the serving threads.

use std::sync::{Mutex, MutexGuard};

/// Lock a mutex, recovering the data if a previous holder panicked.
///
/// The serving loops (`worker_loop`, `NativeWorker`, `Server`) must not
/// die because some other thread panicked while holding a shared lock:
/// every structure guarded this way (pool sets, the response channel)
/// keeps its invariants per-operation, so the data inside a poisoned
/// mutex is still usable — take it and keep serving instead of
/// propagating the panic to a second thread.
pub fn lock_recover<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}
