//! A small scoped thread pool (no rayon/tokio offline).
//!
//! Two facilities:
//! * [`ThreadPool`] — long-lived worker pool with a shared injector queue,
//!   used by the serving coordinator for background work.
//! * [`parallel_for`] — fork-join helper that splits an index range over
//!   scoped threads; used by batch quantization and eval sweeps. On this
//!   single-core CI box it degrades gracefully to near-sequential cost.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

use crate::util::sync::lock_recover;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Shared completion accounting: `pending` counts jobs submitted but not
/// yet finished; `idle` is signalled whenever it drops to zero so
/// [`ThreadPool::wait_idle`] can sleep instead of spinning.
struct PoolState {
    pending: Mutex<usize>,
    idle: Condvar,
}

/// Fixed-size pool executing boxed jobs FIFO.
///
/// Panic-safe: a job that panics is caught on the worker, the worker
/// stays alive for the next job, and the pending count is still
/// decremented — `wait_idle` never hangs on a panicking workload and
/// pool capacity never silently shrinks.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    state: Arc<PoolState>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let state = Arc::new(PoolState { pending: Mutex::new(0), idle: Condvar::new() });
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let state = Arc::clone(&state);
                thread::Builder::new()
                    .name(format!("pq-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                // Run outside the rx lock; swallow panics so
                                // one bad job can't kill the worker or leak
                                // the pending count.
                                let _ = catch_unwind(AssertUnwindSafe(job));
                                let mut n = lock_recover(&state.pending);
                                *n = n.saturating_sub(1);
                                if *n == 0 {
                                    state.idle.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed → shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers, state }
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        *lock_recover(&self.state.pending)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        *lock_recover(&self.state.pending) += 1;
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Block until all submitted jobs have completed. Condvar-based:
    /// sleeps between completions instead of burning a core on
    /// `yield_now`, and is woken by the worker that drains the count
    /// to zero — including when the draining job panicked.
    pub fn wait_idle(&self) {
        let mut n = lock_recover(&self.state.pending);
        while *n > 0 {
            n = match self.state.idle.wait(n) {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Fork-join over `0..n`: calls `f(i)` for every i, splitting the range in
/// contiguous chunks across up to `threads` scoped threads.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let f = &f;
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            s.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Fork-join over disjoint mutable slabs (§Perf): splits `items` into
/// contiguous chunks across up to `threads` scoped threads and calls
/// `f(global_index, &mut item)` for every element. The mutable-slab
/// variant head-parallel decode rides on — each (layer, head) task owns
/// its scratch slab with no locking.
pub fn parallel_for_mut<T: Send, F: Fn(usize, &mut T) + Sync>(
    items: &mut [T],
    threads: usize,
    f: F,
) {
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for (i, item) in items.iter_mut().enumerate() {
            f(i, item);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for (t, slab) in items.chunks_mut(chunk).enumerate() {
            let f = &f;
            let lo = t * chunk;
            s.spawn(move || {
                for (k, item) in slab.iter_mut().enumerate() {
                    f(lo + k, item);
                }
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in order.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
        parallel_for(n, threads, |i| {
            let mut slot = slots[i].lock().unwrap();
            **slot = Some(f(i));
        });
    }
    out.into_iter().map(|x| x.expect("parallel_map slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not hang
        assert!(counter.load(Ordering::SeqCst) <= 10);
    }

    #[test]
    fn panicking_job_neither_hangs_nor_shrinks_pool() {
        let pool = ThreadPool::new(2);
        for _ in 0..4 {
            pool.execute(|| panic!("job panics"));
        }
        // Regression: the old pool decremented pending only after job()
        // returned, so a panic leaked the count and this spun forever.
        pool.wait_idle();
        assert_eq!(pool.pending(), 0);

        // Capacity is intact: park every worker on a barrier that only
        // opens once each one arrives — deadlocks (and trips the recv
        // timeout) if a worker thread died with the panics above.
        let barrier = Arc::new(std::sync::Barrier::new(2));
        let (tx, rx) = mpsc::channel();
        for _ in 0..2 {
            let b = Arc::clone(&barrier);
            let tx = tx.clone();
            pool.execute(move || {
                b.wait();
                tx.send(()).unwrap();
            });
        }
        for _ in 0..2 {
            rx.recv_timeout(std::time::Duration::from_secs(10))
                .expect("both workers alive after panicking jobs");
        }
        pool.wait_idle();
    }

    #[test]
    fn wait_idle_blocks_until_jobs_finish() {
        let pool = ThreadPool::new(1);
        let done = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&done);
        pool.execute(move || {
            thread::sleep(std::time::Duration::from_millis(50));
            d.fetch_add(1, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallel_for_mut_disjoint_slabs() {
        let mut slabs = vec![0u64; 23];
        parallel_for_mut(&mut slabs, 4, |i, v| *v = i as u64 + 1);
        for (i, v) in slabs.iter().enumerate() {
            assert_eq!(*v, i as u64 + 1);
        }
        let mut empty: Vec<u64> = Vec::new();
        parallel_for_mut(&mut empty, 4, |_, _| panic!("no calls"));
        let mut one = [7u64];
        parallel_for_mut(&mut one, 4, |i, v| *v += i as u64 + 1);
        assert_eq!(one[0], 8);
    }

    #[test]
    fn parallel_for_covers_range() {
        let hits: Vec<AtomicU64> = (0..57).map(|_| AtomicU64::new(0)).collect();
        parallel_for(57, 4, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_map_ordered() {
        let v = parallel_map(20, 3, |i| i * i);
        assert_eq!(v, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_for_zero_and_one() {
        parallel_for(0, 4, |_| panic!("no calls"));
        let hit = AtomicU64::new(0);
        parallel_for(1, 4, |_| {
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }
}
