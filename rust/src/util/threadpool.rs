//! A small scoped thread pool (no rayon/tokio offline).
//!
//! Two facilities:
//! * [`ThreadPool`] — long-lived worker pool with a shared injector queue,
//!   used by the serving coordinator for background work.
//! * [`parallel_for`] — fork-join helper that splits an index range over
//!   scoped threads; used by batch quantization and eval sweeps. On this
//!   single-core CI box it degrades gracefully to near-sequential cost.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size pool executing boxed jobs FIFO.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                thread::Builder::new()
                    .name(format!("pq-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                job();
                                queued.fetch_sub(1, Ordering::SeqCst);
                            }
                            Err(_) => break, // channel closed → shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers, queued }
    }

    /// Number of jobs submitted but not yet finished.
    pub fn pending(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Block until all submitted jobs have completed.
    pub fn wait_idle(&self) {
        while self.pending() > 0 {
            thread::yield_now();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Fork-join over `0..n`: calls `f(i)` for every i, splitting the range in
/// contiguous chunks across up to `threads` scoped threads.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let f = &f;
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            s.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Map `f` over `0..n` in parallel, collecting results in order.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots: Vec<Mutex<&mut Option<T>>> = out.iter_mut().map(Mutex::new).collect();
        parallel_for(n, threads, |i| {
            let mut slot = slots[i].lock().unwrap();
            **slot = Some(f(i));
        });
    }
    out.into_iter().map(|x| x.expect("parallel_map slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not hang
        assert!(counter.load(Ordering::SeqCst) <= 10);
    }

    #[test]
    fn parallel_for_covers_range() {
        let hits: Vec<AtomicU64> = (0..57).map(|_| AtomicU64::new(0)).collect();
        parallel_for(57, 4, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_map_ordered() {
        let v = parallel_map(20, 3, |i| i * i);
        assert_eq!(v, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_for_zero_and_one() {
        parallel_for(0, 4, |_| panic!("no calls"));
        let hit = AtomicU64::new(0);
        parallel_for(1, 4, |_| {
            hit.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hit.load(Ordering::SeqCst), 1);
    }
}
