//! Wall-clock timing + the hand-rolled bench runner (criterion is not
//! available offline, so `cargo bench` targets use `harness = false` and
//! this module for measurement/reporting).

use std::time::{Duration, Instant};

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Measurement result for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn throughput_line(&self, items_per_iter: f64, unit: &str) -> String {
        format!(
            "{:<44} {:>12.3} ms/iter  {:>12.1} {}/s",
            self.name,
            self.mean_s * 1e3,
            items_per_iter / self.mean_s,
            unit
        )
    }
}

/// Benchmark `f` adaptively: warm up, pick an iteration count targeting
/// `target_s` seconds of total measurement, then report per-iteration stats
/// over `samples` batches.
pub fn bench<F: FnMut()>(name: &str, target_s: f64, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let t = Instant::now();
    f();
    let first = t.elapsed().as_secs_f64().max(1e-9);
    let samples = 5u64;
    let iters_per_sample = ((target_s / samples as f64 / first).ceil() as u64).max(1);

    let mut means = Vec::with_capacity(samples as usize);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters_per_sample {
            f();
        }
        means.push(t.elapsed().as_secs_f64() / iters_per_sample as f64);
    }
    let mean = means.iter().sum::<f64>() / means.len() as f64;
    let var = means.iter().map(|m| (m - mean).powi(2)).sum::<f64>() / means.len() as f64;
    let min = means.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = means.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    BenchResult {
        name: name.to_string(),
        iters: samples * iters_per_sample,
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: min,
        max_s: max,
    }
}

/// Print a standard bench header like the criterion text reporter.
pub fn print_result(r: &BenchResult) {
    println!(
        "{:<44} time: [{:.4} ms  {:.4} ms  {:.4} ms]  ({} iters)",
        r.name,
        r.min_s * 1e3,
        r.mean_s * 1e3,
        r.max_s * 1e3,
        r.iters
    );
}

/// Format a duration human-readably.
pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 0.02, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s + 1e-12);
        assert!(r.iters >= 5);
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(2e-9).contains("ns"));
        assert!(fmt_duration(2e-6).contains("µs"));
        assert!(fmt_duration(2e-3).contains("ms"));
        assert!(fmt_duration(2.0).contains(" s"));
    }
}
