//! Cross-layer parity: the AOT artifacts (L1 Pallas kernels + L2 JAX
//! model, lowered to HLO and executed through PJRT) must agree with the
//! native Rust implementations. Requires `make artifacts`; tests skip
//! with a loud message if the artifacts are missing (CI runs them via
//! `make test`, which builds artifacts first).

// The PJRT engine is feature-gated (needs the external `xla` crate); the
// whole suite compiles away on the default offline build.
#![cfg(feature = "pjrt")]

use polarquant::model::transformer::Transformer;
use polarquant::model::weights::Weights;
use polarquant::polar::quantizer::{PolarConfig, PolarQuantizer};
use polarquant::runtime::artifacts::Manifest;
use polarquant::runtime::engine::{lit_f32, lit_i32, to_f32_vec, to_i32_vec, PjrtEngine};
use polarquant::runtime::model_runtime::PjrtModel;
use polarquant::util::rng::{Pcg64, Rng};
use polarquant::util::stats::rel_l2_error;

// The PJRT client holds `Rc` internals (not Sync), so each test builds its
// own engine rather than sharing a static.
fn engine() -> Option<PjrtEngine> {
    let dir = Manifest::default_dir();
    if !Manifest::available(&dir) {
        eprintln!("SKIP: no artifacts at {dir}/ — run `make artifacts`");
        return None;
    }
    Some(PjrtEngine::new(&dir).expect("engine"))
}

fn gaussian(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_gaussian(&mut v);
    v
}

/// Build a rust quantizer wired to the manifest's layout, using the
/// manifest-recorded codebooks (which python derived analytically — they
/// must match rust's own analytic books; asserted separately below).
fn manifest_quantizer(eng: &PjrtEngine) -> PolarQuantizer {
    let codec = &eng.manifest.codec;
    let cfg = PolarConfig {
        dim: codec.head_dim,
        levels: codec.levels,
        level_bits: codec.level_bits.clone(),
        precondition: polarquant::math::rotation::PreconditionKind::Haar,
        seed: 0x504f4c4152,
    };
    PolarQuantizer::new_offline(cfg)
}

#[test]
fn python_and_rust_analytic_codebooks_agree() {
    let Some(eng) = engine() else { return };
    let eng = &eng;
    let pq = manifest_quantizer(eng);
    for (l, (cent_py, bnd_py)) in eng.manifest.codebooks.iter().enumerate() {
        let book = &pq.codebooks.books[l];
        assert_eq!(book.centroids.len(), cent_py.len(), "level {}", l + 1);
        for (a, b) in book.centroids.iter().zip(cent_py) {
            assert!(
                (a - b).abs() < 2e-3,
                "level {} centroid {a} vs python {b}",
                l + 1
            );
        }
        for (a, b) in book.boundaries.iter().zip(bnd_py) {
            assert!((a - b).abs() < 2e-3, "level {} boundary {a} vs {b}", l + 1);
        }
    }
}

#[test]
fn polar_encode_artifact_matches_rust_codec() {
    let Some(eng) = engine() else { return };
    let eng = &eng;
    let codec = &eng.manifest.codec;
    let (n, d) = (codec.enc_n, codec.head_dim);
    let pq = manifest_quantizer(eng);

    let x = gaussian(n * d, 42);
    // Extract the rust rotation matrix to feed the graph.
    let rot = rotation_matrix(&pq, d);
    let mut args = vec![
        lit_f32(&x, &[n, d]).unwrap(),
        lit_f32(&rot, &[d, d]).unwrap(),
    ];
    for book in &pq.codebooks.books {
        args.push(lit_f32(&book.boundaries, &[book.boundaries.len()]).unwrap());
    }
    let out = eng.run("polar_encode", &args).expect("run polar_encode");
    assert_eq!(out.len(), 1 + codec.levels);

    // Compare radii and codes against the rust codec, row by row.
    let radii_hlo = to_f32_vec(&out[0]).unwrap();
    let codes_hlo: Vec<Vec<i32>> =
        (1..out.len()).map(|i| to_i32_vec(&out[i]).unwrap()).collect();
    let nr = d >> codec.levels;
    let mut mismatched_codes = 0usize;
    let mut total_codes = 0usize;
    for t in 0..n {
        let enc = pq.encode(&x[t * d..(t + 1) * d]);
        for j in 0..nr {
            let r_rust = polarquant::quant::fp16::f16_bits_to_f32(enc.radii[j]);
            let r_hlo = radii_hlo[t * nr + j];
            assert!(
                (r_rust - r_hlo).abs() < 0.01 * r_hlo.abs().max(1.0),
                "radius t={t} j={j}: {r_rust} vs {r_hlo}"
            );
        }
        // Unpack rust codes and compare (tolerate boundary-tie flips).
        let mut reader = polarquant::polar::pack::BitReader::new(&enc.codes);
        for l in 0..codec.levels {
            let count = d >> (l + 1);
            for a in 0..count {
                let rust_code = reader.read(codec.level_bits[l]) as i32;
                let hlo_code = codes_hlo[l][t * count + a];
                total_codes += 1;
                if rust_code != hlo_code {
                    mismatched_codes += 1;
                }
            }
        }
    }
    // Codes may differ only on exact boundary ties / circular wrap cells —
    // a tiny fraction.
    let frac = mismatched_codes as f64 / total_codes as f64;
    assert!(frac < 0.02, "code mismatch fraction {frac}");
}

#[test]
fn quantized_attention_artifact_matches_rust_path() {
    let Some(eng) = engine() else { return };
    let eng = &eng;
    let codec = &eng.manifest.codec;
    let (n, d, b) = (codec.enc_n, codec.head_dim, codec.score_b);
    let pq = manifest_quantizer(eng);
    let rot = rotation_matrix(&pq, d);

    let keys = gaussian(n * d, 7);
    let values = gaussian(n * d, 8);
    let q = gaussian(b * d, 9);

    // Encode with the rust codec, hand codes to the HLO graph.
    let (k_radii, k_codes) = encode_planes(&pq, &keys, n, d, codec.levels);
    let (v_radii, v_codes) = encode_planes(&pq, &values, n, d, codec.levels);

    let nr = d >> codec.levels;
    let mut args = vec![
        lit_f32(&q, &[b, d]).unwrap(),
        lit_f32(&rot, &[d, d]).unwrap(),
        lit_f32(&k_radii, &[n, nr]).unwrap(),
        lit_f32(&v_radii, &[n, nr]).unwrap(),
    ];
    for l in 0..codec.levels {
        args.push(lit_i32(&k_codes[l], &[n, d >> (l + 1)]).unwrap());
    }
    for l in 0..codec.levels {
        args.push(lit_i32(&v_codes[l], &[n, d >> (l + 1)]).unwrap());
    }
    for book in &pq.codebooks.books {
        args.push(lit_f32(&book.centroids, &[book.centroids.len()]).unwrap());
    }
    let out = eng
        .run("quantized_attention", &args)
        .expect("run quantized_attention");
    let hlo_out = to_f32_vec(&out[0]).unwrap();

    // Native path: same math via the rust codec.
    let mut rust_out = vec![0.0f32; b * d];
    {
        let mut k_hat = vec![0.0f32; n * d];
        let mut v_hat = vec![0.0f32; n * d];
        let mut buf = vec![0.0f32; d];
        for t in 0..n {
            let ck = pq.encode(&keys[t * d..(t + 1) * d]);
            pq.decode_preconditioned(&ck, &mut buf);
            k_hat[t * d..(t + 1) * d].copy_from_slice(&buf);
            let cv = pq.encode(&values[t * d..(t + 1) * d]);
            pq.decode_preconditioned(&cv, &mut buf);
            v_hat[t * d..(t + 1) * d].copy_from_slice(&buf);
        }
        let scale = 1.0 / (d as f32).sqrt();
        let mut rq = vec![0.0f32; d];
        let mut scores = vec![0.0f32; n];
        for bi in 0..b {
            pq.precondition_query(&q[bi * d..(bi + 1) * d], &mut rq);
            for t in 0..n {
                scores[t] =
                    polarquant::math::linalg::dot(&k_hat[t * d..(t + 1) * d], &rq) * scale;
            }
            polarquant::math::linalg::softmax(&mut scores);
            let mut acc = vec![0.0f32; d];
            for t in 0..n {
                let w = scores[t];
                for j in 0..d {
                    acc[j] += w * v_hat[t * d + j];
                }
            }
            pq.rotation
                .apply_t(&acc, &mut rust_out[bi * d..(bi + 1) * d]);
        }
    }
    let rel = rel_l2_error(&hlo_out, &rust_out);
    assert!(rel < 2e-2, "quantized attention parity rel error {rel}");
}

#[test]
fn pjrt_model_matches_native_transformer() {
    let Some(eng) = engine() else { return };
    let eng = &eng;
    let dir = Manifest::default_dir();
    let wfile = eng.manifest.weights_file.clone().expect("weights in manifest");
    let weights = Weights::load(&format!("{dir}/{wfile}")).expect("load weights");
    let pjrt = PjrtModel::new(eng, &weights).expect("pjrt model");
    let mut native = Transformer::new(weights);

    // Prefill parity on a short prompt.
    let tokens: Vec<u32> = (0..24).map(|i| (i * 13 + 3) % native.cfg.vocab as u32).collect();
    let (logits_hlo, _, _) = pjrt.prefill_chunk(&tokens).expect("pjrt prefill");
    let native_out = native.prefill(&tokens);
    let vocab = native.cfg.vocab;
    for t in [0usize, 7, 23] {
        let h = &logits_hlo[t * vocab..(t + 1) * vocab];
        let n = &native_out.logits[t * vocab..(t + 1) * vocab];
        let rel = rel_l2_error(h, n);
        assert!(rel < 2e-3, "prefill logits t={t}: rel {rel}");
        // Same argmax → same greedy generation.
        assert_eq!(
            polarquant::math::linalg::argmax(h),
            polarquant::math::linalg::argmax(n),
            "greedy token at t={t}"
        );
    }

    // Decode parity: teacher-force 4 steps through the PJRT cache buffers.
    let (_, k, v) = pjrt.prefill_chunk(&tokens).unwrap();
    let mut kv = pjrt.fresh_kv();
    // Copy prefill K/V (L, S, H, Dh) into the decode buffers (L, MAX, H, Dh).
    let (l_, h_, dh) = (native.cfg.n_layers, native.cfg.n_heads, native.cfg.head_dim);
    let s = eng.manifest.prefill_s;
    for li in 0..l_ {
        for t in 0..tokens.len() {
            let src = (li * s + t) * h_ * dh;
            let new_k = &k[src..src + h_ * dh];
            let new_v = &v[src..src + h_ * dh];
            let base = (li * eng.manifest.decode_maxlen + t) * h_ * dh;
            kv.k[base..base + h_ * dh].copy_from_slice(new_k);
            kv.v[base..base + h_ * dh].copy_from_slice(new_v);
        }
    }
    kv.len = tokens.len();

    // Native caches (exact method).
    use polarquant::kvcache::sequence::{CacheConfig, SequenceCache};
    let pre = native.prefill(&tokens);
    let mut caches = SequenceCache::from_prefill(
        &native.cfg,
        &CacheConfig::new("exact", 1.0),
        &pre,
    );

    let mut tok = polarquant::math::linalg::argmax(pre.last_logits(vocab)).unwrap() as u32;
    for step in 0..4 {
        let pos = tokens.len() + step;
        let hlo_logits = pjrt.decode_step(tok, pos, &mut kv).expect("pjrt decode");
        let native_logits = native.decode_step(tok, pos, &mut caches.caches);
        let rel = rel_l2_error(&hlo_logits, &native_logits);
        assert!(rel < 2e-2, "decode step {step}: rel {rel}");
        tok = polarquant::math::linalg::argmax(&hlo_logits).unwrap() as u32;
    }
}

// -- helpers ----------------------------------------------------------------

fn rotation_matrix(pq: &PolarQuantizer, d: usize) -> Vec<f32> {
    match &pq.rotation {
        polarquant::math::rotation::Rotation::Dense { m, .. } => m.clone(),
        _ => {
            // Identity fallback.
            let mut m = vec![0.0f32; d * d];
            for i in 0..d {
                m[i * d + i] = 1.0;
            }
            m
        }
    }
}

/// Encode a batch with the rust codec, returning fp16-rounded radii +
/// per-level unpacked i32 code planes (the HLO interface layout).
fn encode_planes(
    pq: &PolarQuantizer,
    rows: &[f32],
    n: usize,
    d: usize,
    levels: usize,
) -> (Vec<f32>, Vec<Vec<i32>>) {
    let nr = d >> levels;
    let mut radii = vec![0.0f32; n * nr];
    let mut codes: Vec<Vec<i32>> =
        (0..levels).map(|l| vec![0i32; n * (d >> (l + 1))]).collect();
    for t in 0..n {
        let enc = pq.encode(&rows[t * d..(t + 1) * d]);
        for j in 0..nr {
            radii[t * nr + j] = polarquant::quant::fp16::f16_bits_to_f32(enc.radii[j]);
        }
        let mut reader = polarquant::polar::pack::BitReader::new(&enc.codes);
        for l in 0..levels {
            let count = d >> (l + 1);
            for a in 0..count {
                codes[l][t * count + a] =
                    reader.read(pq.cfg.level_bits[l]) as i32;
            }
        }
    }
    (radii, codes)
}
