//! Cross-substrate parity under codec-sized page geometry: decode over
//! pool-backed page slots must reproduce the legacy per-sequence
//! `CompressedKv` heap path — bit-identically for fp16, within codec
//! tolerance for polarquant — and a prefix-cache hit must reproduce a
//! cold prefill exactly, for both page-aligned and mid-page divergence
//! splits. Pools here are sized to each codec's exact `slot_bytes()`
//! (no slack bytes), so these tests also pin that the new geometry
//! changes nothing about the bytes any kernel reads. Also pins the
//! accounting invariant: every pool's `memory_bytes` equals its live
//! pages counted once at that codec's width (the pools are the only KV
//! store).

use polarquant::coordinator::request::{GenRequest, Tracked};
use polarquant::coordinator::scheduler::Scheduler;
use polarquant::coordinator::worker::NativeWorker;
use polarquant::kvcache::codec::{page_codec_for, KvLayout, PageCodec};
use polarquant::kvcache::paged::{PageId, PagedConfig, PagedPool};
use polarquant::kvcache::pools::{share_pools, PoolSet};
use polarquant::kvcache::sequence::{CacheConfig, SequenceCache};
use polarquant::model::config::ModelConfig;
use polarquant::model::transformer::{PrefillOutput, Transformer};
use polarquant::model::weights::Weights;
use std::collections::BTreeSet;

/// Encode a prefill's K/V rows into a sequence's pool slots — the same
/// write the engine's pooled prefill performs.
fn encode_prompt(
    pool: &mut PagedPool,
    seq: u64,
    codec: &dyn PageCodec,
    layout: &KvLayout,
    cfg: &ModelConfig,
    pre: &PrefillOutput,
    upto: usize,
) {
    let (hd, dh) = (cfg.n_heads * cfg.head_dim, cfg.head_dim);
    for t in 0..upto {
        let slot = pool.token_slot_mut(seq, t).expect("slot");
        for (l, layer) in pre.kv.iter().enumerate() {
            for h in 0..cfg.n_heads {
                codec.cell_codec(l, h).encode_pair(
                    &layer.keys[t * hd + h * dh..t * hd + (h + 1) * dh],
                    &layer.values[t * hd + h * dh..t * hd + (h + 1) * dh],
                    &mut slot[layout.pair_range(l, h)],
                );
            }
        }
    }
}

/// A standalone pool sized to exactly this codec's slot width — the new
/// geometry every serving pool now uses.
fn sized_pool(layout: &KvLayout, tokens: usize) -> PagedPool {
    PagedPool::new(PagedConfig {
        page_tokens: 4,
        token_bytes: layout.slot_bytes(),
        num_pages: tokens.div_ceil(4) + 8,
    })
}

#[test]
fn fp16_pool_decode_bit_identical_to_legacy_heap() {
    // The fp16 page codec stores exactly what the legacy `ExactKv` heap
    // cache stores, and the slot readers replay the same op order —
    // teacher-forced decode logits must match bit for bit, including
    // the decode-appended tail (fp16 in both substrates). The pool's
    // token slots are exactly fp16-wide: no slack region exists at all.
    let cfg = ModelConfig::test();
    let mut m = Transformer::synthetic(&cfg, 42);
    let tokens: Vec<u32> = (0..40).map(|i| (i * 13 + 5) % 64).collect();
    let split = 32;
    let pre = m.prefill(&tokens[..split]);

    let mut legacy = SequenceCache::from_prefill(&cfg, &CacheConfig::new("exact", 1.0), &pre);
    let codec = page_codec_for("fp16", cfg.head_dim).unwrap();
    let layout = KvLayout::new(&cfg, codec.as_ref());
    let mut pool = sized_pool(&layout, tokens.len() + 4);
    assert_eq!(pool.cfg.token_bytes, layout.slot_bytes(), "no slack bytes");
    pool.register(1, tokens.len() + 4).unwrap();
    encode_prompt(&mut pool, 1, codec.as_ref(), &layout, &cfg, &pre, split);

    for (i, &t) in tokens[split..].iter().enumerate() {
        let pos = split + i;
        let a = m.decode_step(t, pos, &mut legacy.caches);
        let b = m.decode_step_paged(t, pos, &mut pool, 1, codec.as_ref(), &layout);
        assert_eq!(a, b, "step {pos}: fp16 pool logits must be bit-identical");
    }
}

#[test]
fn polar_pool_decode_matches_legacy_heap() {
    // Same encoded codes, same fused score/accumulate kernels → the
    // first decode step (no appended tail yet) is bit-identical. Later
    // steps diverge only in tail storage (legacy keeps an fp16 tail per
    // paper §5.3; the pool encodes streamed tokens with the codec) and
    // must stay within quantization tolerance. Pool slots are exactly
    // polar-wide (≈4 bits/coord) — the geometry the server now runs.
    let cfg = ModelConfig::test();
    let mut m = Transformer::synthetic(&cfg, 7);
    let tokens: Vec<u32> = (0..36).map(|i| (i * 7 + 1) % 64).collect();
    let split = 32;
    let pre = m.prefill(&tokens[..split]);

    let mut legacy = SequenceCache::from_prefill(
        &cfg,
        &CacheConfig::new("polarquant-r-offline", 0.25),
        &pre,
    );
    let codec = page_codec_for("polarquant-r-offline", cfg.head_dim).unwrap();
    let layout = KvLayout::new(&cfg, codec.as_ref());
    let mut pool = sized_pool(&layout, tokens.len() + 4);
    pool.register(1, tokens.len() + 4).unwrap();
    encode_prompt(&mut pool, 1, codec.as_ref(), &layout, &cfg, &pre, split);

    for (i, &t) in tokens[split..].iter().enumerate() {
        let pos = split + i;
        let a = m.decode_step(t, pos, &mut legacy.caches);
        let b = m.decode_step_paged(t, pos, &mut pool, 1, codec.as_ref(), &layout);
        if i == 0 {
            assert_eq!(a, b, "step {pos}: identical codes → identical logits");
        } else {
            let rel = polarquant::util::stats::rel_l2_error(&b, &a);
            assert!(rel < 0.5, "step {pos}: rel divergence {rel}");
        }
    }
}

fn run_to_done(
    s: &mut Scheduler,
    e: &mut NativeWorker,
) -> Vec<polarquant::coordinator::request::GenResponse> {
    let mut done = Vec::new();
    while !s.active.is_empty() {
        done.extend(s.decode_round(e).finished);
    }
    done
}

fn exact_req(id: u64, prompt: &[u32]) -> Tracked {
    let mut r = GenRequest::new(id, prompt.to_vec(), 4);
    r.method = "exact".into();
    Tracked::new(r)
}

/// A fresh prefix-caching stack over codec-sized pools.
fn stack(cfg: &ModelConfig) -> (Scheduler, NativeWorker) {
    let pools = share_pools(PoolSet::for_model(cfg, 16, 2048));
    let engine = NativeWorker::with_pools(Weights::synthetic(cfg, 9), pools.clone());
    (Scheduler::with_prefix_cache_shared(pools, 4, 1 << 20), engine)
}

#[test]
fn scheduler_prefix_hit_then_decode_matches_cold_prefill_exactly() {
    // End-to-end acceptance: a radix hit serves decode directly from
    // shared pool pages (no snapshot store exists anymore), and with
    // the lossless exact codec the warm generation is token-identical
    // to a cold one — now over a pool whose slots are exactly the
    // codec's width. Also asserts the pool-bytes invariant while
    // sequences and cache share pages.
    let cfg = ModelConfig::test();
    let prompt: Vec<u32> = (0..48).map(|i| (i * 5 + 2) % 64).collect();

    // Cold reference on a fresh stack.
    let (mut s0, mut e0) = stack(&cfg);
    s0.admit(vec![exact_req(1, &prompt)], &mut e0);
    let cold = run_to_done(&mut s0, &mut e0).remove(0);
    assert_eq!(cold.reused_tokens, 0);

    // Warm: same stack, second sighting hits the radix cache.
    let (mut s1, mut e1) = stack(&cfg);
    s1.admit(vec![exact_req(1, &prompt)], &mut e1);
    run_to_done(&mut s1, &mut e1);
    s1.admit(vec![exact_req(2, &prompt)], &mut e1);

    // Accounting invariant while the warm sequence is active and shares
    // its head with the cache: every live page counted once, at the
    // exact codec's own width.
    {
        let pools = s1.pools.lock().unwrap();
        let pool = pools.pool("exact").unwrap();
        let mut unique: BTreeSet<PageId> = BTreeSet::new();
        if let Some(t) = pool.table(2) {
            unique.extend(t.pages.iter().copied());
        }
        // The cache's pages are exactly the shared head of table 2 here,
        // so the union of live block tables covers every live page.
        assert_eq!(
            unique.len() * pool.page_bytes(),
            pool.memory_bytes(),
            "pool bytes must equal live slot bytes, shared pages once"
        );
        assert_eq!(pool.live_pages().len(), unique.len());
    }

    let warm = run_to_done(&mut s1, &mut e1).remove(0);
    // 48 tokens = 3 full pages; an exact repeat clamps one token back so
    // the suffix forward pass has a row to produce logits from.
    assert_eq!(warm.reused_tokens, 47);
    assert_eq!(
        warm.tokens, cold.tokens,
        "prefix hit + decode must reproduce the cold generation exactly"
    );

    let ev = s1.take_prefix_events();
    assert_eq!((ev.hits, ev.misses), (1, 1));
    assert_eq!(ev.tokens_reused, 47);
}

#[test]
fn mid_page_divergence_split_matches_cold_prefill_exactly() {
    // The divergence-split path under sized pages: prompt B shares a
    // page-aligned head with cached prompt A but diverges mid-page
    // (token 24 of a 16-token page grid), so only the first full page
    // can be reused and the partial page is re-prefilled. The warm B
    // generation must still be token-identical to a cold B run.
    let cfg = ModelConfig::test();
    let head: Vec<u32> = (0..24).map(|i| (i * 3 + 1) % 64).collect();
    let mut a = head.clone();
    a.extend((24..48).map(|i| (i * 5 + 2) % 64));
    let mut b = head;
    b.extend((24..48).map(|i| (i * 7 + 5) % 64)); // diverges at token 24

    // Cold reference for B.
    let (mut s0, mut e0) = stack(&cfg);
    s0.admit(vec![exact_req(1, &b)], &mut e0);
    let cold_b = run_to_done(&mut s0, &mut e0).remove(0);

    // Warm: A seeds the cache, then B hits only the aligned head.
    let (mut s1, mut e1) = stack(&cfg);
    s1.admit(vec![exact_req(1, &a)], &mut e1);
    run_to_done(&mut s1, &mut e1);
    s1.admit(vec![exact_req(2, &b)], &mut e1);
    let warm_b = run_to_done(&mut s1, &mut e1).remove(0);
    assert_eq!(
        warm_b.reused_tokens, 16,
        "divergence inside page 2 caps reuse at the page boundary"
    );
    assert_eq!(warm_b.tokens, cold_b.tokens, "mid-page split must not change output");

    // And a later full-A repeat still gets the page-aligned A match.
    s1.admit(vec![exact_req(3, &a)], &mut e1);
    let warm_a = run_to_done(&mut s1, &mut e1).remove(0);
    assert_eq!(warm_a.reused_tokens, 47, "A's own path survives the split");
}

#[test]
fn kivi_and_polar_pool_scores_stay_finite_end_to_end() {
    // Smoke parity for the remaining page codecs through the real
    // scheduler: generations complete, report their true slot footprint,
    // and decode never produces non-finite logits (sampled ids in
    // vocab). Both quantized slot layouts must undercut fp16.
    let cfg = ModelConfig::test();
    let pools = share_pools(PoolSet::for_model(&cfg, 16, 4096));
    let mut engine = NativeWorker::with_pools(Weights::synthetic(&cfg, 3), pools.clone());
    let mut sched = Scheduler::with_prefix_cache_shared(pools, 4, 1 << 20);
    let prompt: Vec<u32> = (0..32).map(|i| (i * 3 + 2) % 64).collect();
    let mut bytes = std::collections::BTreeMap::new();
    for (id, method) in ["polarquant-r-offline", "kivi", "fp16"].iter().enumerate() {
        let mut r = GenRequest::new(id as u64 + 1, prompt.clone(), 4);
        r.method = (*method).to_string();
        sched.admit(vec![Tracked::new(r)], &mut engine);
        let resp = run_to_done(&mut sched, &mut engine).remove(0);
        assert_eq!(resp.tokens.len(), 4, "{method}");
        assert!(resp.tokens.iter().all(|&t| (t as usize) < cfg.vocab), "{method}");
        assert!(resp.cache_bytes > 0, "{method}");
        bytes.insert(*method, resp.cache_bytes);
    }
    assert!(
        bytes["polarquant-r-offline"] < bytes["fp16"] && bytes["kivi"] < bytes["fp16"],
        "quantized slots must undercut fp16: {bytes:?}"
    );
    // Under codec-sized geometry the *pools* show the same ordering in
    // actual resident bytes (the cache still references prompt pages).
    let pools = sched.pools.lock().unwrap();
    let page = |m: &str| pools.pool(m).unwrap().page_bytes();
    assert!(page("polarquant-r-offline") < page("fp16"));
    assert!(page("kivi") < page("fp16"));
}

#[test]
fn adaptive_pool_serving_stays_finite_and_never_outspends_uniform_polar() {
    // The adaptive codec through the real scheduler: generations
    // complete with in-vocab tokens, decode stays finite across mixed
    // per-(layer, head) cell widths, and — the allocation's default
    // budget being the uniform polar width — its pool pages never
    // outspend `polarquant-r-offline`'s. A custom-budget spec routes to
    // its *own* pool at its own (strictly smaller) width.
    let cfg = ModelConfig::test();
    let pools = share_pools(PoolSet::for_model(&cfg, 16, 4096));
    let mut engine = NativeWorker::with_pools(Weights::synthetic(&cfg, 3), pools.clone());
    let mut sched = Scheduler::with_prefix_cache_shared(pools, 4, 1 << 20);
    let prompt: Vec<u32> = (0..32).map(|i| (i * 3 + 2) % 64).collect();
    let methods = ["adaptive", "adaptive:budget=3.25", "polarquant-r-offline"];
    for (id, method) in methods.iter().enumerate() {
        let mut r = GenRequest::new(id as u64 + 1, prompt.clone(), 4);
        r.method = (*method).to_string();
        sched.admit(vec![Tracked::new(r)], &mut engine);
        let resp = run_to_done(&mut sched, &mut engine).remove(0);
        assert_eq!(resp.tokens.len(), 4, "{method}");
        assert!(resp.tokens.iter().all(|&t| (t as usize) < cfg.vocab), "{method}");
        assert!(resp.cache_bytes > 0, "{method}");
    }
    let pools = sched.pools.lock().unwrap();
    let page = |m: &str| pools.pool(m).unwrap().page_bytes();
    assert!(
        page("adaptive") <= page("polarquant-r-offline"),
        "default budget must not outspend uniform polar"
    );
    assert!(
        page("adaptive:budget=3.25") < page("adaptive"),
        "a tighter budget buys a strictly smaller pool page"
    );
}
