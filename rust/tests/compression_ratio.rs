//! Compression-ratio regression suite: pins that PolarQuant's headline
//! memory claim is real in **resident bytes**, not just in code width.
//!
//! With codec-sized pools ([`PoolSet`]), a pool's `memory_bytes` is the
//! analytic slot cost of its codec — so these tests turn the paper's
//! numbers into enforced invariants:
//!
//! * every page codec's pool bytes match `pages × page_tokens ×
//!   slot_bytes(codec)` exactly (no slack, no worst-case sizing);
//! * polarquant keeps the same token stream resident in ≤ 1/4 the bytes
//!   of the exact f32 codec (measured: ≈8.3x vs exact, ≈4.1x vs fp16 —
//!   the paper's ×4.2);
//! * KIVI's in-slot zero/scale constants are visible as bits/coord
//!   strictly above its 2-bit code width (2 + 32/G), while PolarQuant's
//!   normalization-free layout stays ≤ 4 bits with no constants at all.

use polarquant::coordinator::request::{GenRequest, Tracked};
use polarquant::coordinator::scheduler::Scheduler;
use polarquant::coordinator::worker::NativeWorker;
use polarquant::kvcache::codec::{
    codec_for_model, max_slot_bytes, AdaptivePageCodec, KvLayout, PAGE_CODEC_METHODS,
};
use polarquant::kvcache::pools::{share_pools, PoolSet};
use polarquant::model::config::ModelConfig;
use polarquant::model::weights::Weights;

const PAGE_TOKENS: usize = 16;

fn layout_for(cfg: &ModelConfig, method: &str) -> KvLayout {
    let codec = codec_for_model(method, cfg).expect("page codec");
    KvLayout::new(cfg, codec.as_ref())
}

#[test]
fn memory_bytes_matches_analytic_slot_cost_exactly() {
    // Fixed workload: 3 sequences of 40, 55 and 64 tokens. For every
    // page codec, the pool's resident bytes must equal the analytic
    // page cost at that codec's exact slot width — byte for byte.
    let cfg = ModelConfig::mini();
    for method in PAGE_CODEC_METHODS {
        let mut pools = PoolSet::for_model(&cfg, PAGE_TOKENS, 4096);
        let layout = layout_for(&cfg, method);
        assert_eq!(
            pools.token_bytes_for(method),
            layout.slot_bytes(),
            "{method}: slot width is the codec layout, no slack"
        );
        let pool = pools.pool_mut(method);
        let mut expect_pages = 0usize;
        for (seq, tokens) in [(1u64, 40usize), (2, 55), (3, 64)] {
            pool.register(seq, tokens).unwrap();
            expect_pages += tokens.div_ceil(PAGE_TOKENS);
        }
        let analytic = expect_pages * PAGE_TOKENS * layout.slot_bytes();
        assert_eq!(
            pool.memory_bytes(),
            analytic,
            "{method}: resident bytes must equal the analytic slot cost"
        );
        // And through the set-level occupancy, bits/coord is the
        // codec's achieved width exactly.
        let (bytes, slots) = pools.occupancy();
        let cpt = cfg.kv_coords_per_token();
        let bits = bytes as f64 * 8.0 / (slots * cpt) as f64;
        let want = layout.slot_bytes() as f64 * 8.0 / cpt as f64;
        assert!((bits - want).abs() < 1e-9, "{method}: {bits} vs {want}");
    }
}

#[test]
fn achieved_bits_per_coord_match_the_paper_layouts() {
    // The slot-layout table as regression-checked numbers (d=64, the
    // mini model): exact 32, fp16 16, kivi 2 + 32/G = 3.0 at G=32,
    // polarquant 3.875 (fp16 radii + byte-rounded packed angles).
    let cfg = ModelConfig::mini();
    let cpt = cfg.kv_coords_per_token() as f64;
    let bits = |method: &str| layout_for(&cfg, method).slot_bytes() as f64 * 8.0 / cpt;
    assert_eq!(bits("exact"), 32.0);
    assert_eq!(bits("fp16"), 16.0);
    assert_eq!(bits("kivi"), 3.0, "2-bit codes + in-slot zero/scale headers");
    assert_eq!(bits("polarquant"), 3.875);
    assert_eq!(bits("polarquant-r-offline"), 3.875);
    // KIVI's overhead claim as an inequality: strictly above its pure
    // code width (2 bits) — the in-slot constants ARE the difference —
    // while polar carries no constants and stays ≤ 4 bits.
    assert!(bits("kivi") > 2.0);
    assert!(bits("polarquant-r-offline") <= 4.0);
    // Adaptive defaults its budget to the uniform polar width, so its
    // achieved bits/coord never exceed 3.875 — and a sane allocation
    // spends most of it.
    assert!(bits("adaptive") <= 3.875);
    assert!(bits("adaptive") > 3.0, "solver left most of the budget unspent");
}

#[test]
fn adaptive_resident_bytes_pin_the_solver_budget() {
    // The solver's spend IS the resident cost: for both the default
    // budget (= uniform polar bits) and an explicit one, the layout's
    // slot width equals the allocation's `slot_bytes()`, never exceeds
    // `budget_bytes`, and pool pages are priced at exactly that width.
    let cfg = ModelConfig::mini();
    for (method, budget) in [("adaptive", None), ("adaptive:budget=3.25", Some(3.25))] {
        let codec = AdaptivePageCodec::build(method, budget, &cfg).expect("solvable");
        let alloc = codec.allocation();
        let layout = KvLayout::new(&cfg, &codec);
        assert_eq!(layout.slot_bytes(), alloc.slot_bytes(), "{method}");
        assert!(
            alloc.slot_bytes() <= alloc.budget_bytes,
            "{method}: spend {} over budget {}",
            alloc.slot_bytes(),
            alloc.budget_bytes
        );
        // Greedy stops when no whole upgrade fits — the remainder is
        // bounded by the widest single-level upgrade, not proportional
        // to the budget. A byte-tight pin that still permits it:
        assert!(
            alloc.budget_bytes - alloc.slot_bytes() < 32,
            "{method}: {} of {} budget bytes unspent",
            alloc.budget_bytes - alloc.slot_bytes(),
            alloc.budget_bytes
        );
        // The pool prices pages at exactly this width.
        let mut pools = PoolSet::for_model(&cfg, PAGE_TOKENS, 4096);
        assert_eq!(pools.token_bytes_for(method), alloc.slot_bytes(), "{method}");
        let pool = pools.pool_mut(method);
        pool.register(1, 40).unwrap();
        let pages = 40usize.div_ceil(PAGE_TOKENS);
        assert_eq!(pool.memory_bytes(), pages * PAGE_TOKENS * alloc.slot_bytes(), "{method}");
    }
    // The explicit budget must be the binding constraint (not a no-op).
    let a = AdaptivePageCodec::build("adaptive", None, &cfg).unwrap();
    let b = AdaptivePageCodec::build("adaptive:budget=3.25", Some(3.25), &cfg).unwrap();
    assert!(b.allocation().slot_bytes() < a.allocation().slot_bytes());
    // `describe()` is the allocation-inspection surface (see the verify
    // skill): one line per (layer, head) with K/V level widths.
    let desc = a.allocation().describe();
    assert!(desc.lines().count() >= cfg.n_layers * cfg.n_heads);
    assert!(desc.contains("L0"), "describe names layers:\n{desc}");
}

/// Encode the same prompt through the real engine for `method` and
/// return the resident encoded-KV bytes its pool holds.
fn resident_after_prefill(cfg: &ModelConfig, method: &str, prompt: &[u32]) -> usize {
    let pools = share_pools(PoolSet::for_model(cfg, PAGE_TOKENS, 2048));
    let mut w = NativeWorker::with_pools(Weights::synthetic(cfg, 11), pools.clone());
    let mut req = GenRequest::new(1, prompt.to_vec(), 2);
    req.method = method.into();
    let (eid, first) = w.prefill(&req);
    let t = w.decode(eid, first, prompt.len());
    assert!((t as usize) < cfg.vocab, "{method}: decode stays sane");
    let (bytes, slots) = pools.lock().unwrap().occupancy();
    assert!(slots > 0, "{method}: prompt resident");
    bytes
}

#[test]
fn polarquant_resident_bytes_at_most_quarter_of_exact() {
    // The acceptance criterion, end to end through the engine: the same
    // token stream (prompt + decode budget) resides in ≤ 1/4 the bytes
    // under polarquant vs the exact codec — and every codec's residency
    // undercuts exact (no codec pays the old worst-case width anymore).
    let cfg = ModelConfig::test();
    let prompt: Vec<u32> = (0..48).map(|i| (i * 13 + 3) % 64).collect();
    let exact = resident_after_prefill(&cfg, "exact", &prompt);
    let polar = resident_after_prefill(&cfg, "polarquant-r-offline", &prompt);
    let fp16 = resident_after_prefill(&cfg, "fp16", &prompt);
    let kivi = resident_after_prefill(&cfg, "kivi", &prompt);
    assert!(
        polar * 4 <= exact,
        "polarquant must be ≥4x smaller resident: polar {polar} vs exact {exact}"
    );
    assert_eq!(fp16 * 2, exact, "fp16 residency is exactly half of f32");
    assert!(kivi < fp16, "kivi undercuts fp16");
    for (name, b) in [("fp16", fp16), ("kivi", kivi), ("polar", polar)] {
        assert!(b < exact, "{name} must not report exact-width residency");
    }
}

#[test]
fn mixed_codec_serving_accounts_each_method_at_its_own_width() {
    // The serving-shaped version: one scheduler + engine over shared
    // codec-sized pools, the same fixed workload admitted under each
    // codec. Per-codec pool residency must reproduce the analytic
    // ratios vs exact — with pages (not just slots) as the unit, since
    // both pools see identical token counts and page geometry.
    let cfg = ModelConfig::test();
    let pools = share_pools(PoolSet::for_model(&cfg, PAGE_TOKENS, 4096));
    let mut engine = NativeWorker::with_pools(Weights::synthetic(&cfg, 5), pools.clone());
    let mut sched = Scheduler::with_prefix_cache_shared(pools.clone(), 8, 1 << 20);
    let prompt: Vec<u32> = (0..40).map(|i| (i * 7 + 2) % 64).collect();
    for (id, method) in PAGE_CODEC_METHODS.iter().enumerate() {
        let mut r = GenRequest::new(id as u64 + 1, prompt.clone(), 4);
        r.method = (*method).to_string();
        sched.admit(vec![Tracked::new(r)], &mut engine);
    }
    while !sched.active.is_empty() {
        sched.decode_round(&mut engine);
    }
    // All sequences retired; the prefix cache keeps each codec's prompt
    // pages resident — the same page count per codec, priced at each
    // codec's own width.
    let pools = pools.lock().unwrap();
    let exact = pools.pool("exact").unwrap();
    let polar = pools.pool("polarquant-r-offline").unwrap();
    assert_eq!(exact.used_pages(), polar.used_pages(), "same cached pages");
    assert!(exact.used_pages() > 0);
    assert!(
        polar.memory_bytes() * 4 <= exact.memory_bytes(),
        "polar cache residency ≥4x under exact: {} vs {}",
        polar.memory_bytes(),
        exact.memory_bytes()
    );
    // The exact pool is the only one at reference width.
    for method in PAGE_CODEC_METHODS.iter().filter(|m| **m != "exact") {
        let p = pools.pool(method).unwrap();
        assert!(
            p.memory_bytes() < exact.memory_bytes(),
            "{method} must not report exact-width residency"
        );
        assert!(p.cfg.token_bytes < max_slot_bytes(&cfg));
    }
}
