//! Vectorized page-kernel parity: for every page codec, one
//! `key_scores_page`/`value_accumulate_page` call over a multi-slot run
//! must be bit-identical to scoring/accumulating the same slots one at
//! a time (the scalar path — which for the polar codec the quantizer's
//! own unit tests pin against `score_slot`/`accumulate_slot`). Runs
//! cover full pages, partial pages and odd counts, and the fused
//! softmax-max each batch call returns must equal the fold over the
//! per-slot scores, bitwise. A second suite pins that head-parallel
//! paged decode is a pure scheduling change: logits at every fan-out
//! width match the single-threaded run bit for bit.

use polarquant::kvcache::codec::{
    codec_for_model, page_codec_for, CodecScratch, KvLayout, PageCodec, PAGE_CODEC_METHODS,
};
use polarquant::kvcache::paged::{PagedConfig, PagedPool};
use polarquant::model::config::ModelConfig;
use polarquant::model::transformer::{PrefillOutput, Transformer};
use polarquant::polar::quantizer::BlockScratch;
use polarquant::util::rng::{Pcg64, Rng};

fn gaussian(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed);
    let mut v = vec![0.0f32; n];
    rng.fill_gaussian(&mut v);
    v
}

/// Page geometry the serving pools use in the model tests: counts below
/// exercise partial pages (1..3), one exactly-full page (4) and a run
/// spanning page-plus (7) — odd counts included on purpose, they hit
/// the unrolled kernels' remainder loops.
const PAGE_TOKENS: usize = 4;
const COUNTS: [usize; 5] = [1, 2, 3, PAGE_TOKENS, 7];

/// The batch-vs-scalar bitwise parity battery for one codec (or one
/// adaptive *cell* codec) at dimension `d`. `label` names the codec in
/// failure messages; `seed0` de-correlates the data across cells.
fn check_codec_parity(label: &str, codec: &dyn PageCodec, d: usize, seed0: u64) {
    let n = *COUNTS.iter().max().unwrap();
    let pb = codec.pair_bytes(d);
    // Pair mid-slot with slack on both sides, like a real multi-head
    // layout; surrounding garbage pins that kernels read only their
    // own pair's bytes.
    let offset = 5;
    let stride = offset + pb + 3;
    let mut buf = vec![0xA5u8; n * stride + 11];
    for i in 0..n {
        let k = gaussian(d, seed0 + 100 + i as u64);
        let v = gaussian(d, seed0 + 200 + i as u64);
        codec.encode_pair(&k, &v, &mut buf[i * stride + offset..][..pb]);
    }
    let q = gaussian(d, 9);

    // Independent scratches: the batch side must not be able to lean
    // on state the scalar side left behind, or vice versa.
    let mut sc_batch = CodecScratch::default();
    let mut sc_slot = CodecScratch::default();
    codec.prepare_query(&q, &mut sc_batch);
    codec.prepare_query(&q, &mut sc_slot);

    for &count in &COUNTS {
        // --- key scores: one batch call vs count single-slot calls.
        let mut got = Vec::new();
        let got_max =
            codec.key_scores_page(&buf, stride, offset, count, &q, &mut sc_batch, &mut got);
        let mut want = Vec::new();
        let mut want_max = f32::NEG_INFINITY;
        for i in 0..count {
            let m = codec.key_scores_page(
                &buf[i * stride..],
                stride,
                offset,
                1,
                &q,
                &mut sc_slot,
                &mut want,
            );
            if m > want_max {
                want_max = m;
            }
        }
        assert_eq!(got.len(), count, "{label} count={count}");
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            let msg = format!("{label} count={count} slot {i}: batch {g} vs scalar {w}");
            assert_eq!(g.to_bits(), w.to_bits(), "{msg}");
        }
        let msg = format!("{label} count={count}: max {got_max} vs fold {want_max}");
        assert_eq!(got_max.to_bits(), want_max.to_bits(), "{msg}");

        // --- value accumulate: zero weights mixed in (the masked-slot
        // skip must not perturb bits — adding 0.0 is not a bitwise
        // no-op in IEEE 754).
        let w: Vec<f32> = (0..count)
            .map(|i| if i % 3 == 1 { 0.0 } else { 0.1 + 0.05 * i as f32 })
            .collect();
        let seed_acc: Vec<f32> = (0..d).map(|j| 0.25 + j as f32 * 1e-3).collect();
        let mut acc_batch = seed_acc.clone();
        let mut acc_slot = seed_acc;
        let mut blk_batch = BlockScratch::default();
        let mut blk_slot = BlockScratch::default();
        codec.value_accumulate_page(
            &buf,
            stride,
            offset,
            count,
            &w,
            &mut blk_batch,
            &mut acc_batch,
        );
        for i in 0..count {
            codec.value_accumulate_page(
                &buf[i * stride..],
                stride,
                offset,
                1,
                &w[i..i + 1],
                &mut blk_slot,
                &mut acc_slot,
            );
        }
        for (j, (a, b)) in acc_batch.iter().zip(&acc_slot).enumerate() {
            let msg = format!("{label} count={count} acc[{j}]: batch {a} vs scalar {b}");
            assert_eq!(a.to_bits(), b.to_bits(), "{msg}");
        }
    }

    // --- empty run: NEG_INFINITY max, nothing scored or accumulated.
    let mut got = Vec::new();
    let m = codec.key_scores_page(&buf, stride, offset, 0, &q, &mut sc_batch, &mut got);
    assert!(got.is_empty() && m == f32::NEG_INFINITY, "{label} empty run");
    let mut acc = vec![0.5f32; d];
    codec.value_accumulate_page(
        &buf,
        stride,
        offset,
        0,
        &[],
        &mut BlockScratch::default(),
        &mut acc,
    );
    assert!(acc.iter().all(|&x| x == 0.5), "{label} empty accumulate");
}

#[test]
fn page_kernels_bitwise_match_single_slot_calls() {
    let d = 64;
    for method in PAGE_CODEC_METHODS {
        // Model-spanning codecs (adaptive) have no dim-only constructor;
        // their per-cell kernels are covered below in
        // `adaptive_cells_page_kernels_bitwise_match_single_slot_calls`.
        let Some(codec) = page_codec_for(method, d) else {
            assert_eq!(method, "adaptive", "{method} must be page-native at d={d}");
            continue;
        };
        check_codec_parity(method, codec.as_ref(), d, 0);
    }
}

#[test]
fn adaptive_cells_page_kernels_bitwise_match_single_slot_calls() {
    // Every (layer, head) cell of the adaptive codec runs the same
    // block kernels at its own code widths — the full battery must hold
    // bitwise for each, and the solver must actually produce mixed
    // widths (else this test degenerates into the uniform one).
    let cfg = ModelConfig::mini();
    let codec = codec_for_model("adaptive", &cfg).expect("adaptive solves at the paper budget");
    let d = cfg.head_dim;
    let mut widths = std::collections::BTreeSet::new();
    for l in 0..cfg.n_layers {
        for h in 0..cfg.n_heads {
            let cell = codec.cell_codec(l, h);
            widths.insert(cell.pair_bytes(d));
            let label = format!("adaptive[L{l}H{h}]");
            check_codec_parity(&label, cell, d, (l * 31 + h * 7) as u64);
        }
    }
    assert!(widths.len() > 1, "bit allocation must produce mixed per-cell widths");
}

/// Encode a prefill's K/V rows into a sequence's pool slots — the same
/// write the engine's pooled prefill performs.
fn encode_prompt(
    pool: &mut PagedPool,
    seq: u64,
    codec: &dyn PageCodec,
    layout: &KvLayout,
    cfg: &ModelConfig,
    pre: &PrefillOutput,
    upto: usize,
) {
    let (hd, dh) = (cfg.n_heads * cfg.head_dim, cfg.head_dim);
    for t in 0..upto {
        let slot = pool.token_slot_mut(seq, t).expect("slot");
        for (l, layer) in pre.kv.iter().enumerate() {
            for h in 0..cfg.n_heads {
                codec.cell_codec(l, h).encode_pair(
                    &layer.keys[t * hd + h * dh..t * hd + (h + 1) * dh],
                    &layer.values[t * hd + h * dh..t * hd + (h + 1) * dh],
                    &mut slot[layout.pair_range(l, h)],
                );
            }
        }
    }
}

fn sized_pool(layout: &KvLayout, tokens: usize) -> PagedPool {
    PagedPool::new(PagedConfig {
        page_tokens: PAGE_TOKENS,
        token_bytes: layout.slot_bytes(),
        num_pages: tokens.div_ceil(PAGE_TOKENS) + 8,
    })
}

#[test]
fn head_parallel_decode_bitwise_matches_single_threaded() {
    // Head-parallel decode must be a pure scheduling change: every
    // (layer, head) task owns its scratch slab and writes a disjoint
    // output row, so logits at any fan-out width are bit-identical to
    // the single-threaded run. Covered for the block-kernel polar codec
    // and a per-slot codec (fp16); widths 2 and 4 exercise both uneven
    // and exact head splits over the 4-head test model. `adaptive` adds
    // mixed per-(layer, head) cell widths under the same invariant.
    let cfg = ModelConfig::test();
    let mut m = Transformer::synthetic(&cfg, 11);
    let tokens: Vec<u32> = (0..44).map(|i| (i * 11 + 3) % 64).collect();
    let split = 32; // past PARALLEL_MIN_TOKENS, so auto-sizing would fan out too
    let pre = m.prefill(&tokens[..split]);

    for method in ["polarquant-r-offline", "fp16", "adaptive"] {
        let codec = codec_for_model(method, &cfg).expect("page codec");
        let layout = KvLayout::new(&cfg, codec.as_ref());
        let mut runs: Vec<Vec<Vec<f32>>> = Vec::new();
        for &threads in &[1usize, 2, 4] {
            m.set_decode_threads(Some(threads));
            let mut pool = sized_pool(&layout, tokens.len() + PAGE_TOKENS);
            pool.register(1, tokens.len() + PAGE_TOKENS).unwrap();
            encode_prompt(&mut pool, 1, codec.as_ref(), &layout, &cfg, &pre, split);
            let mut out = Vec::new();
            for (i, &t) in tokens[split..].iter().enumerate() {
                let logits =
                    m.decode_step_paged(t, split + i, &mut pool, 1, codec.as_ref(), &layout);
                assert!(logits.iter().all(|x| x.is_finite()), "{method} t{threads}");
                out.push(logits.to_vec());
            }
            runs.push(out);
        }
        m.set_decode_threads(None);
        for (w, run) in runs[1..].iter().enumerate() {
            for (step, (a, b)) in runs[0].iter().zip(run).enumerate() {
                for (j, (x, y)) in a.iter().zip(b).enumerate() {
                    let msg = format!("{method} width {} step {step} logit {j}", [2, 4][w]);
                    assert_eq!(x.to_bits(), y.to_bits(), "{msg}");
                }
            }
        }
    }
}
