//! Golden-grammar tests for the `/metrics` Prometheus text exposition:
//! every line must parse against the text-format grammar (metric names,
//! label pairs, values), every family must carry `# HELP`/`# TYPE` and
//! keep its samples contiguous, histograms must have monotone cumulative
//! buckets ending at `+Inf` with a matching `_count`, and summaries must
//! carry `_sum`/`_count`. Plus the multi-worker e2e: per-worker quality
//! labels from every replica merge into one exposition without series
//! collisions, and the TCP `{"cmd": "metrics"}` command round-trips the
//! same payload terminated by a blank line.

use polarquant::coordinator::batcher::BatchPolicy;
use polarquant::coordinator::request::GenRequest;
use polarquant::coordinator::server::{run_tcp, Server, ServerConfig};
use polarquant::model::config::ModelConfig;
use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

fn server(workers: usize, quality_every: usize, round_robin: bool) -> Server {
    Server::start(ServerConfig {
        model: ModelConfig::test(),
        seed: 2,
        workers,
        batch: BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() },
        pool_tokens: 1 << 14,
        max_active: 4,
        prefix_cache: true,
        prefix_routing: !round_robin,
        round_robin,
        quality_sample_every: quality_every,
        ..Default::default()
    })
}

/// Worker count for the multi-worker merge test; the CI job pins it via
/// `PQ_E2E_WORKERS` (same contract as `serving_e2e.rs`).
fn e2e_workers() -> usize {
    std::env::var("PQ_E2E_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
        .max(2)
}

// ---------------------------------------------------------------------------
// The grammar checker: a line-by-line parser of the text exposition.
// ---------------------------------------------------------------------------

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

type Labels = BTreeMap<String, String>;

/// One parsed sample line: `name{labels} value` or `name value`.
fn parse_sample(line: &str) -> (String, Labels, f64) {
    let (name_labels, value) = line.rsplit_once(' ').unwrap_or_else(|| {
        panic!("sample line has no value: {line:?}");
    });
    let value = match value {
        "+Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        "NaN" => f64::NAN,
        v => v.parse().unwrap_or_else(|e| panic!("bad value {v:?} in {line:?}: {e}")),
    };
    let (name, labels) = match name_labels.split_once('{') {
        None => (name_labels.to_string(), Labels::new()),
        Some((name, rest)) => {
            let body = rest
                .strip_suffix('}')
                .unwrap_or_else(|| panic!("unterminated label set: {line:?}"));
            let mut labels = Labels::new();
            for pair in body.split(',') {
                let (k, v) = pair
                    .split_once("=\"")
                    .unwrap_or_else(|| panic!("bad label pair {pair:?} in {line:?}"));
                let v = v
                    .strip_suffix('"')
                    .unwrap_or_else(|| panic!("unterminated label value in {line:?}"));
                assert!(valid_label_name(k), "bad label name {k:?} in {line:?}");
                assert!(!v.contains('"') && !v.contains('\\'), "unescaped label {v:?}");
                assert!(
                    labels.insert(k.to_string(), v.to_string()).is_none(),
                    "duplicate label {k:?} in {line:?}"
                );
            }
            (name.to_string(), labels)
        }
    };
    assert!(valid_metric_name(&name), "bad metric name {name:?} in {line:?}");
    (name, labels, value)
}

struct Exposition {
    /// Family name -> declared TYPE.
    families: BTreeMap<String, String>,
    /// Every sample in exposition order.
    samples: Vec<(String, Labels, f64)>,
}

impl Exposition {
    fn values_of(&self, name: &str) -> Vec<(&Labels, f64)> {
        self.samples.iter().filter(|(n, ..)| n == name).map(|(_, l, v)| (l, *v)).collect()
    }
}

/// Parse the whole exposition, enforcing the grammar: HELP immediately
/// followed by TYPE, one declaration per family, samples contiguous
/// under their declaring family with kind-appropriate names, no
/// duplicate series.
fn check_exposition(text: &str) -> Exposition {
    let mut families: BTreeMap<String, String> = BTreeMap::new();
    let mut samples: Vec<(String, Labels, f64)> = Vec::new();
    let mut seen_series: BTreeSet<String> = BTreeSet::new();
    let mut current: Option<String> = None;
    let mut pending_help: Option<String> = None;
    for (ln, line) in text.lines().enumerate() {
        let at = || format!("line {}: {line:?}", ln + 1);
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let (name, help) = rest.split_once(' ').unwrap_or_else(|| panic!("{}", at()));
            assert!(valid_metric_name(name), "{}", at());
            assert!(!help.trim().is_empty(), "empty HELP: {}", at());
            assert!(pending_help.is_none(), "HELP without TYPE before {}", at());
            assert!(!families.contains_key(name), "family {name} declared twice: {}", at());
            pending_help = Some(name.to_string());
        } else if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) = rest.split_once(' ').unwrap_or_else(|| panic!("{}", at()));
            assert_eq!(pending_help.as_deref(), Some(name), "TYPE must follow HELP: {}", at());
            pending_help = None;
            assert!(
                ["counter", "gauge", "histogram", "summary"].contains(&kind),
                "unknown TYPE {kind:?}: {}",
                at()
            );
            families.insert(name.to_string(), kind.to_string());
            current = Some(name.to_string());
        } else if line.starts_with('#') {
            panic!("unknown comment form: {}", at());
        } else {
            let (name, labels, value) = parse_sample(line);
            let fam = current.clone().unwrap_or_else(|| panic!("sample before TYPE: {}", at()));
            let kind = families[&fam].as_str();
            let member = match kind {
                "counter" | "gauge" => name == fam,
                "summary" => {
                    name == fam || name == format!("{fam}_sum") || name == format!("{fam}_count")
                }
                "histogram" => {
                    name == format!("{fam}_bucket")
                        || name == format!("{fam}_sum")
                        || name == format!("{fam}_count")
                }
                _ => false,
            };
            assert!(member, "sample {name} outside contiguous family {fam} ({kind}): {}", at());
            if kind == "histogram" && name.ends_with("_bucket") {
                assert!(labels.contains_key("le"), "bucket without le: {}", at());
            }
            if kind == "summary" && name == fam {
                assert!(labels.contains_key("quantile"), "summary without quantile: {}", at());
            }
            if kind == "counter" {
                assert!(value >= 0.0, "negative counter: {}", at());
            }
            let series = format!("{name}{labels:?}");
            assert!(seen_series.insert(series), "duplicate series: {}", at());
            samples.push((name, labels, value));
        }
    }
    assert!(pending_help.is_none(), "dangling # HELP at end of exposition");
    Exposition { families, samples }
}

/// Histogram invariants per (family, label-set-minus-le) series group:
/// cumulative buckets never decrease, the last bucket is `+Inf`, and it
/// equals the series' `_count`; `_sum` exists.
fn check_histograms(exp: &Exposition) {
    for (fam, kind) in &exp.families {
        if kind != "histogram" {
            continue;
        }
        // Group in exposition order; label sets minus `le` key each series.
        let mut groups: Vec<(Labels, Vec<(String, f64)>)> = Vec::new();
        for (name, labels, value) in &exp.samples {
            if name != &format!("{fam}_bucket") {
                continue;
            }
            let mut key = labels.clone();
            let le = key.remove("le").expect("bucket has le");
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, v)) => v.push((le, *value)),
                None => groups.push((key, vec![(le, *value)])),
            }
        }
        assert!(!groups.is_empty(), "histogram family {fam} has no buckets");
        for (key, buckets) in &groups {
            let mut last = f64::NEG_INFINITY;
            let mut prev_le = f64::NEG_INFINITY;
            for (le, v) in buckets {
                assert!(*v >= last, "{fam}{key:?}: bucket le={le} decreases ({v} < {last})");
                last = *v;
                if le != "+Inf" {
                    let le_v: f64 = le.parse().unwrap_or_else(|e| {
                        panic!("{fam}{key:?}: unparseable le {le:?}: {e}")
                    });
                    assert!(le_v > prev_le, "{fam}{key:?}: le edges not increasing at {le}");
                    prev_le = le_v;
                }
            }
            assert_eq!(
                buckets.last().map(|(le, _)| le.as_str()),
                Some("+Inf"),
                "{fam}{key:?}: last bucket must be +Inf"
            );
            let count = exp
                .values_of(&format!("{fam}_count"))
                .into_iter()
                .find(|(l, _)| *l == key)
                .unwrap_or_else(|| panic!("{fam}{key:?}: missing _count"))
                .1;
            assert_eq!(last, count, "{fam}{key:?}: +Inf bucket must equal _count");
            assert!(
                exp.values_of(&format!("{fam}_sum")).iter().any(|(l, _)| **l == *key),
                "{fam}{key:?}: missing _sum"
            );
        }
    }
}

/// Summary invariants: every summary family exposes `_sum` and a
/// non-negative `_count` alongside its quantiles.
fn check_summaries(exp: &Exposition) {
    for (fam, kind) in &exp.families {
        if kind != "summary" {
            continue;
        }
        assert!(
            exp.samples.iter().any(|(n, l, _)| n == fam && l.contains_key("quantile")),
            "summary {fam} has no quantile samples"
        );
        let counts = exp.values_of(&format!("{fam}_count"));
        assert!(!counts.is_empty(), "summary {fam} missing _count");
        assert!(counts.iter().all(|(_, v)| *v >= 0.0), "summary {fam} negative _count");
        assert!(!exp.values_of(&format!("{fam}_sum")).is_empty(), "summary {fam} missing _sum");
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[test]
fn metrics_exposition_parses_line_by_line() {
    let s = server(1, 4, false);
    for method in ["polarquant-r-offline", "exact"] {
        let mut req = GenRequest::new(0, (0..40).map(|x| x % 64).collect(), 6);
        req.method = method.into();
        s.generate_blocking(req, Duration::from_secs(60)).expect("response");
    }
    let text = s.metrics_text();
    let exp = check_exposition(&text);
    check_histograms(&exp);
    check_summaries(&exp);

    // The full /stats surface is on the wire: gauges, the percentile
    // summaries (with the observed-count satellite), per-worker gauges.
    assert_eq!(exp.families.get("pq_requests_done").map(String::as_str), Some("gauge"));
    assert_eq!(exp.families.get("pq_ttft").map(String::as_str), Some("summary"));
    let ttft_count = exp.values_of("pq_ttft_count")[0].1;
    assert!(ttft_count >= 2.0, "ttft summary count covers both requests: {ttft_count}");
    assert!(exp.families.contains_key("pq_worker_requests_done"));

    // And the quality families, with per-cell labels.
    assert_eq!(exp.families.get("kv_quality_samples_total").map(String::as_str), Some("counter"));
    assert_eq!(exp.families.get("kv_quality_angle_code").map(String::as_str), Some("histogram"));
    assert_eq!(exp.families.get("kv_quality_radius").map(String::as_str), Some("histogram"));
    let polar_samples: f64 = exp
        .values_of("kv_quality_samples_total")
        .iter()
        .filter(|(l, _)| l.get("codec").map(String::as_str) == Some("polarquant-r-offline"))
        .map(|(_, v)| *v)
        .sum();
    assert!(polar_samples > 0.0, "sampled polar cells reach the exposition:\n{text}");
    for (labels, _) in exp.values_of("kv_quality_samples_total") {
        for key in ["worker", "codec", "layer", "head"] {
            assert!(labels.contains_key(key), "cell label {key} missing: {labels:?}");
        }
    }
    s.shutdown();
}

#[test]
fn multi_worker_quality_labels_merge_without_collisions() {
    let workers = e2e_workers();
    // Strict round-robin so every replica sees traffic deterministically.
    let s = server(workers, 2, true);
    let n = workers * 3;
    for i in 0..n {
        let mut req = GenRequest::new(0, (0..32).map(|x| (x * 3 + i as u32) % 64).collect(), 4);
        req.method = "polarquant-r-offline".into();
        s.submit(req);
    }
    for _ in 0..n {
        s.recv_timeout(Duration::from_secs(120)).expect("all requests complete");
    }
    let text = s.metrics_text();
    let exp = check_exposition(&text);
    check_histograms(&exp);

    // One observed-pairs counter per worker, each positive, no collisions
    // (duplicate series would have tripped check_exposition already).
    let mut worker_labels = BTreeSet::new();
    for (labels, value) in exp.values_of("kv_quality_observed_pairs_total") {
        assert!(value > 0.0, "worker {labels:?} observed nothing");
        assert!(worker_labels.insert(labels["worker"].clone()));
    }
    assert_eq!(
        worker_labels.len(),
        workers,
        "every replica reports its own counter: {worker_labels:?}\n{text}"
    );

    // Quality cells from at least two distinct replicas coexist.
    let cell_workers: BTreeSet<String> = exp
        .values_of("kv_quality_samples_total")
        .iter()
        .map(|(l, _)| l["worker"].clone())
        .collect();
    assert!(cell_workers.len() >= 2, "cells merge from multiple workers: {cell_workers:?}");
    s.shutdown();
}

#[test]
fn tcp_metrics_roundtrip_ends_with_blank_line() {
    let s = Arc::new(server(1, 4, false));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let s2 = Arc::clone(&s);
    let h = thread::spawn(move || {
        let _ = run_tcp(s2, listener);
    });
    let mut conn = TcpStream::connect(addr).unwrap();
    writeln!(
        conn,
        r#"{{"prompt": [1,2,3,4,5,6,7,8], "max_new_tokens": 3, "method": "polarquant-r-offline"}}"#
    )
    .unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap(); // generation reply (JSON)

    writeln!(conn, r#"{{"cmd": "metrics"}}"#).unwrap();
    let mut text = String::new();
    loop {
        line.clear();
        let bytes = reader.read_line(&mut line).unwrap();
        assert!(bytes > 0, "connection closed before the blank-line terminator");
        if line.trim().is_empty() {
            break;
        }
        text.push_str(&line);
    }
    let exp = check_exposition(&text);
    assert!(exp.families.contains_key("pq_requests_done"));
    assert!(!exp.values_of("kv_quality_observed_pairs_total").is_empty());

    // The connection still speaks JSON afterwards.
    writeln!(conn, r#"{{"cmd": "shutdown"}}"#).unwrap();
    line.clear();
    let _ = reader.read_line(&mut line);
    drop(conn);
    let _ = TcpStream::connect(addr); // unblock the accept loop
    h.join().unwrap();
    match Arc::try_unwrap(s) {
        Ok(srv) => srv.shutdown(),
        Err(_) => {}
    }
}
