//! Hand-rolled property tests (no proptest offline): randomized invariant
//! checks over the codec, packing, codebooks, paged pool and scheduler,
//! several hundred random cases each, all seeded and deterministic.

use polarquant::kvcache::paged::{PagedConfig, PagedPool};
use polarquant::kvcache::pools::PoolSet;
use polarquant::math::linalg::norm2;
use polarquant::model::config::ModelConfig;
use polarquant::math::rotation::{PreconditionKind, Rotation};
use polarquant::polar::codebook::Codebook;
use polarquant::polar::distribution::AngleDistribution;
use polarquant::polar::quantizer::{PolarConfig, PolarQuantizer};
use polarquant::polar::transform::{polar_forward, polar_inverse};
use polarquant::util::rng::{Pcg64, Rng};

/// Property: polar transform round-trips exactly for any (d, L) and any
/// finite input, including adversarial shapes.
#[test]
fn prop_polar_roundtrip() {
    let mut rng = Pcg64::new(1001);
    for case in 0..300 {
        let level = 1 + (case % 5);
        let blocks = 1 + rng.next_below(8) as usize;
        let d = (1usize << level) * blocks;
        let mut x = vec![0.0f32; d];
        match case % 4 {
            0 => rng.fill_gaussian(&mut x),
            1 => rng.fill_uniform(&mut x, -100.0, 100.0),
            2 => {
                // sparse spikes
                for _ in 0..3 {
                    let i = rng.next_below(d as u64) as usize;
                    x[i] = (rng.gaussian() * 50.0) as f32;
                }
            }
            _ => {
                // tiny magnitudes
                rng.fill_uniform(&mut x, -1e-4, 1e-4);
            }
        }
        let rep = polar_forward(&x, level);
        let mut y = vec![0.0f32; d];
        polar_inverse(&rep, &mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!(
                (a - b).abs() <= 1e-4 * (1.0 + a.abs()),
                "case {case}: {a} vs {b}"
            );
        }
    }
}

/// Property: codec reconstruction norm error is bounded by fp16 radius
/// error + angle-cell error for any input; and decode(encode(x)) is
/// idempotent under re-encode.
#[test]
fn prop_codec_norm_and_idempotence() {
    let mut rng = Pcg64::new(1002);
    let cfg = PolarConfig::paper_default(32);
    let pq = PolarQuantizer::new_offline(cfg);
    for _ in 0..200 {
        let mut x = vec![0.0f32; 32];
        rng.fill_gaussian(&mut x);
        let scale = (rng.next_f64() * 100.0 + 0.01) as f32;
        for v in x.iter_mut() {
            *v *= scale;
        }
        let c = pq.encode(&x);
        let mut y = vec![0.0f32; 32];
        pq.decode(&c, &mut y);
        // Norm preserved within fp16 + rotation noise.
        let (nx, ny) = (norm2(&x), norm2(&y));
        assert!((nx - ny).abs() <= 0.02 * nx + 1e-3, "norms {nx} vs {ny}");
        // Idempotence: encoding the reconstruction yields the same codes.
        let c2 = pq.encode(&y);
        let mut y2 = vec![0.0f32; 32];
        pq.decode(&c2, &mut y2);
        for (a, b) in y.iter().zip(&y2) {
            assert!((a - b).abs() <= 0.02 * nx / 5.0 + 1e-3, "{a} vs {b}");
        }
    }
}

/// Property: quantize maps every angle to the nearest centroid (interval
/// books) / nearest under wrap (circular books).
#[test]
fn prop_codebook_nearest_centroid() {
    let mut rng = Pcg64::new(1003);
    for level in 1..=4 {
        let bits = 1 + (level % 3) as u8 + 1;
        let cb = Codebook::lloyd_max_analytic(level, bits);
        let dist = AngleDistribution::for_level(level);
        let (lo, hi) = dist.support();
        for _ in 0..300 {
            let theta = (lo + rng.next_f64() * (hi - lo)) as f32;
            let idx = cb.quantize(theta) as usize;
            let span = (hi - lo) as f32;
            let dist_to = |c: f32| {
                let raw = (theta - c).abs();
                if cb.circular {
                    raw.min(span - raw)
                } else {
                    raw
                }
            };
            let chosen = dist_to(cb.centroids[idx]);
            for &c in &cb.centroids {
                assert!(
                    chosen <= dist_to(c) + 1e-6,
                    "level {level} θ={theta}: chose {idx} but {c} closer"
                );
            }
        }
    }
}

/// Property: rotations are isometries for every kind and dimension.
#[test]
fn prop_rotation_isometry() {
    let mut rng = Pcg64::new(1004);
    for case in 0..60 {
        let d = 1usize << (2 + case % 5); // 4..64
        let kind = match case % 3 {
            0 => PreconditionKind::None,
            1 => PreconditionKind::Haar,
            _ => PreconditionKind::Hadamard,
        };
        let rot = Rotation::new(kind, d, case as u64);
        let mut x = vec![0.0f32; d];
        rng.fill_gaussian(&mut x);
        let mut y = vec![0.0f32; d];
        rot.apply(&x, &mut y);
        assert!((norm2(&x) - norm2(&y)).abs() < 1e-3 * norm2(&x).max(1.0));
        let mut back = vec![0.0f32; d];
        rot.apply_t(&y, &mut back);
        for (a, b) in x.iter().zip(&back) {
            assert!((a - b).abs() < 1e-3, "{kind:?} d={d}");
        }
    }
}

/// Property: the paged pool never double-allocates a page, never leaks,
/// and refcounts stay consistent under a random op sequence.
#[test]
fn prop_paged_pool_consistency() {
    let mut rng = Pcg64::new(1005);
    for trial in 0..40 {
        let pages = 8 + rng.next_below(64) as usize;
        let mut pool = PagedPool::new(PagedConfig {
            page_tokens: 4,
            token_bytes: 8,
            num_pages: pages,
        });
        let mut live: Vec<u64> = Vec::new();
        let mut next_seq = 0u64;
        for _op in 0..300 {
            match rng.next_below(4) {
                0 => {
                    let tokens = 1 + rng.next_below(24) as usize;
                    if pool.can_admit(tokens) {
                        next_seq += 1;
                        pool.register(next_seq, tokens).unwrap();
                        live.push(next_seq);
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let i = rng.next_below(live.len() as u64) as usize;
                        let seq = live.swap_remove(i);
                        pool.release(seq).unwrap();
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let i = rng.next_below(live.len() as u64) as usize;
                        let _ = pool.append_token(live[i]);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let i = rng.next_below(live.len() as u64) as usize;
                        next_seq += 1;
                        pool.fork(live[i], next_seq).unwrap();
                        live.push(next_seq);
                    }
                }
            }
            // Invariant: used + free == total.
            assert_eq!(pool.used_pages() + pool.free_pages(), pages, "trial {trial}");
        }
        // Releasing everything returns the pool to empty.
        for seq in live.drain(..) {
            pool.release(seq).unwrap();
        }
        assert_eq!(pool.free_pages(), pages, "trial {trial}: pool must drain");
    }
}

/// Property: two codec-sized pools of different slot widths (exact f32
/// vs polarquant) never alias each other's data, and per-pool byte
/// accounting holds at every step, under arbitrary interleavings of
/// `register_with_prefix` / `append_token` / `retain_page` /
/// `release_page` / `release` across both pools — the prefix-cache op
/// mix over the new pool-per-codec geometry.
#[test]
fn prop_sized_pools_never_alias_and_account_exactly() {
    let methods = ["exact", "polarquant-r-offline"];
    let mut rng = Pcg64::new(1007);
    for trial in 0..25 {
        let cfg = ModelConfig::test();
        let pool_tokens = 4 * (8 + rng.next_below(24) as usize);
        let mut pools = PoolSet::for_model(&cfg, 4, pool_tokens);
        let widths: Vec<usize> = methods
            .iter()
            .map(|m| pools.token_bytes_for(m))
            .collect();
        assert!(widths[0] >= 4 * widths[1], "size classes must differ");
        // Per-method live sequences and cache-style retained pages.
        let mut live: Vec<Vec<u64>> = vec![Vec::new(), Vec::new()];
        let mut retained: Vec<Vec<u32>> = vec![Vec::new(), Vec::new()];
        // Sentinel writes: (method idx, seq, token) → byte value.
        let mut written: Vec<(usize, u64, usize, u8)> = Vec::new();
        let mut next_seq = 0u64;
        for op in 0..250 {
            let mi = rng.next_below(2) as usize;
            let method = methods[mi];
            match rng.next_below(5) {
                0 => {
                    // Register, sharing a prefix of a live same-method
                    // sequence when possible (zero-copy head).
                    let tokens = 4 + rng.next_below(16) as usize;
                    let shared: Vec<u32> = if let Some(&src) = live[mi].first() {
                        let pool = pools.pool_mut(method);
                        let t = pool.table(src).unwrap();
                        let n = (t.pages.len().saturating_sub(1))
                            .min(pool.pages_for(tokens).saturating_sub(1));
                        t.pages[..n].to_vec()
                    } else {
                        Vec::new()
                    };
                    next_seq += 1;
                    let pool = pools.pool_mut(method);
                    if pool
                        .register_with_prefix(next_seq, &shared, tokens)
                        .is_ok()
                    {
                        live[mi].push(next_seq);
                        // Stamp a sentinel into the first private token
                        // slot (past the shared head).
                        let t0 = shared.len() * 4;
                        if let Some(slot) = pool.token_slot_mut(next_seq, t0) {
                            let v = (op as u8).wrapping_mul(31).wrapping_add(mi as u8);
                            slot.fill(v);
                            written.retain(|&(m, s, t, _)| {
                                !(m == mi && s == next_seq && t == t0)
                            });
                            written.push((mi, next_seq, t0, v));
                        }
                    }
                }
                1 => {
                    if !live[mi].is_empty() {
                        let i = rng.next_below(live[mi].len() as u64) as usize;
                        let seq = live[mi].swap_remove(i);
                        pools.pool_mut(method).release(seq).unwrap();
                        written.retain(|&(m, s, _, _)| !(m == mi && s == seq));
                    }
                }
                2 => {
                    if !live[mi].is_empty() {
                        let i = rng.next_below(live[mi].len() as u64) as usize;
                        let seq = live[mi][i];
                        let _ = pools.pool_mut(method).append_token(seq);
                    }
                }
                3 => {
                    // Cache-style pin: retain the first page of a live
                    // sequence.
                    if let Some(&seq) = live[mi].last() {
                        let pool = pools.pool_mut(method);
                        let pg = pool.table(seq).unwrap().pages[0];
                        pool.retain_page(pg).unwrap();
                        retained[mi].push(pg);
                    }
                }
                _ => {
                    if !retained[mi].is_empty() {
                        let i = rng.next_below(retained[mi].len() as u64) as usize;
                        let pg = retained[mi].swap_remove(i);
                        pools.pool_mut(method).release_page(pg).unwrap();
                    }
                }
            }
            // Invariants at EVERY step, per pool: bytes == live pages ×
            // this pool's own page size; used + free == capacity.
            let mut total = 0usize;
            for (_, pool) in pools.iter() {
                assert_eq!(
                    pool.memory_bytes(),
                    pool.live_pages().len() * pool.page_bytes(),
                    "trial {trial} op {op}"
                );
                assert_eq!(
                    pool.used_pages() + pool.free_pages(),
                    pool.cfg.num_pages,
                    "trial {trial} op {op}"
                );
                total += pool.memory_bytes();
            }
            assert_eq!(pools.memory_bytes(), total);
            // No aliasing: every sentinel readable and intact — a write
            // through one pool/sequence never bleeds into another.
            for &(m, s, t, v) in &written {
                let pool = pools.pool(methods[m]).unwrap();
                let slot = pool.token_slot(s, t).expect("sentinel slot live");
                assert!(
                    slot.iter().all(|&b| b == v),
                    "trial {trial} op {op}: sentinel clobbered in {} seq {s}",
                    methods[m]
                );
                assert_eq!(slot.len(), pool.cfg.token_bytes);
            }
        }
        // Drain: releasing everything returns both pools to empty.
        for (mi, method) in methods.iter().enumerate() {
            for seq in live[mi].drain(..) {
                pools.pool_mut(method).release(seq).unwrap();
            }
            for pg in retained[mi].drain(..) {
                pools.pool_mut(method).release_page(pg).unwrap();
            }
        }
        assert_eq!(pools.memory_bytes(), 0, "trial {trial}: pools must drain");
    }
}

/// Property: bit accounting (`bits_per_vector`) equals actual encoded
/// storage for random layouts.
#[test]
fn prop_bits_accounting_matches_storage() {
    let mut rng = Pcg64::new(1006);
    for _ in 0..50 {
        let levels = 1 + rng.next_below(4) as usize;
        let blocks = 1 + rng.next_below(6) as usize;
        let d = (1usize << levels) * blocks;
        let level_bits: Vec<u8> = (0..levels).map(|_| 1 + rng.next_below(6) as u8).collect();
        let cfg = PolarConfig {
            dim: d,
            levels,
            level_bits,
            precondition: PreconditionKind::None,
            seed: 9,
        };
        cfg.validate();
        let pq = PolarQuantizer::new_offline(cfg.clone());
        let mut x = vec![0.0f32; d];
        rng.fill_gaussian(&mut x);
        let c = pq.encode(&x);
        assert_eq!(c.storage_bytes() * 8, cfg.bits_per_vector(), "cfg {cfg:?}");
    }
}

/// Property: whenever the router directs a request at a prefix-directory
/// advertiser, that worker's outstanding load is within the imbalance
/// guard of the least-loaded replica (measured before the request's own
/// tokens are charged) — under arbitrary interleavings of advertise /
/// retract / route / complete. Directions only ever point at a current
/// advertiser, and retracted entries stop directing immediately.
#[test]
fn prop_directed_routing_never_exceeds_imbalance_guard() {
    use polarquant::coordinator::router::{RouteKind, Router};
    use polarquant::prefix::PrefixDirectory;
    use std::sync::Arc;
    const M: &str = "polarquant-r-offline";
    let mut rng = Pcg64::new(1011);
    for trial in 0..20 {
        let n = 2 + rng.next_below(4) as usize;
        let guard = 8 * (1 + rng.next_below(16));
        let dir = Arc::new(PrefixDirectory::new(4));
        let r = Router::with_directory(n, Arc::clone(&dir), guard);
        let families: Vec<Vec<u32>> = (0..4)
            .map(|f| (0..16).map(|i| f * 100 + i).collect())
            .collect();
        // Which worker currently advertises each family (at most one in
        // this model, so a directed route has exactly one valid target).
        let mut advertised: Vec<Option<usize>> = vec![None; families.len()];
        let mut inflight: Vec<(usize, usize)> = Vec::new();
        for _ in 0..300 {
            let f = rng.next_below(families.len() as u64) as usize;
            match rng.next_below(4) {
                0 => {
                    if advertised[f].is_none() {
                        let w = rng.next_below(n as u64) as usize;
                        dir.advertise(w, M, &families[f], 4);
                        advertised[f] = Some(w);
                    }
                }
                1 => {
                    if let Some(w) = advertised[f].take() {
                        dir.retract(w, M, &families[f], 4);
                    }
                }
                2 => {
                    let mut p = families[f].clone();
                    p.extend(std::iter::repeat(999).take(rng.next_below(8) as usize));
                    let loads: Vec<u64> = (0..n).map(|w| r.load_of(w)).collect();
                    let rt = r.route(None, M, &p);
                    if rt.kind == RouteKind::Directed {
                        let min = *loads.iter().min().unwrap();
                        assert!(
                            loads[rt.worker] <= min + guard,
                            "trial {trial}: directed load {} vs min {min} + guard {guard}",
                            loads[rt.worker]
                        );
                        assert_eq!(
                            Some(rt.worker),
                            advertised[f],
                            "directions only point at a live advertiser"
                        );
                        assert_eq!(rt.expected_tokens, 16);
                    } else if advertised[f].is_none() {
                        assert_ne!(
                            rt.kind,
                            RouteKind::Directed,
                            "retracted entries must stop directing"
                        );
                    }
                    inflight.push((rt.worker, p.len()));
                }
                _ => {
                    if !inflight.is_empty() {
                        let i = rng.next_below(inflight.len() as u64) as usize;
                        let (w, t) = inflight.swap_remove(i);
                        r.complete(w, t);
                    }
                }
            }
        }
    }
}

/// Property: the prefix directory is an exact mirror of radix-node
/// lifetimes. After any interleaving of insert / true-evict / demote /
/// promote, replaying the published events leaves the directory holding
/// exactly the fingerprints of the tree's live page-aligned prefixes —
/// demoted leaves included (they are still matchable via promotion) —
/// and retraction on evict leaves no dangling worker references.
#[test]
fn prop_directory_mirrors_radix_tree_exactly() {
    use polarquant::kvcache::paged::{PagedConfig, PagedPool};
    use polarquant::kvcache::tier::DiskExtent;
    use polarquant::prefix::{PrefixConfig, PrefixDirectory, RadixPrefixCache};
    use std::collections::BTreeSet;
    const M: &str = "polarquant-r-offline";
    const PT: usize = 4;

    /// In-memory extent store for demote/promote closures.
    struct MemTier {
        blobs: Vec<Vec<u8>>,
    }
    impl MemTier {
        fn write(&mut self, b: &[u8]) -> Option<DiskExtent> {
            self.blobs.push(b.to_vec());
            Some(DiskExtent { offset: self.blobs.len() as u64 - 1, len: b.len() as u32 })
        }
        fn read(&self, e: DiskExtent, buf: &mut [u8]) -> bool {
            let blob = &self.blobs[e.offset as usize];
            buf.copy_from_slice(blob);
            true
        }
    }

    let check = |c: &RadixPrefixCache, dir: &PrefixDirectory, trial: usize| {
        let snap = dir.table_snapshot(M);
        let mut expected = BTreeSet::new();
        for id in c.live_node_ids() {
            let path = c.token_path(id);
            let fps = dir.fingerprints(&path);
            let own = c.node_page_count(id);
            assert_eq!(fps.len() * PT, path.len(), "paths are page-aligned");
            for fp in &fps[fps.len() - own..] {
                assert!(expected.insert(*fp), "fp collision would need 64-bit luck");
            }
        }
        let got: BTreeSet<u64> = snap.keys().copied().collect();
        assert_eq!(got, expected, "trial {trial}: directory != tree coverage");
        for workers in snap.values() {
            assert_eq!(workers[..], [0], "trial {trial}: dangling worker ref");
        }
    };

    let mut rng = Pcg64::new(1012);
    for trial in 0..12 {
        let mut pool = PagedPool::new(PagedConfig {
            page_tokens: PT,
            token_bytes: 2,
            num_pages: 512,
        });
        let mut c =
            RadixPrefixCache::new(PrefixConfig { page_tokens: PT, max_pages: usize::MAX });
        c.set_publish(true);
        let dir = PrefixDirectory::new(PT);
        let mut tier = MemTier { blobs: Vec::new() };
        let mut disk_nodes: Vec<usize> = Vec::new();
        let mut next_seq = 0u64;
        for _ in 0..150 {
            match rng.next_below(5) {
                0 | 1 => {
                    // Insert: family head (2 pages) + random tail, so
                    // runs share heads and split on divergence.
                    let fam = rng.next_below(3) as u32;
                    let mut p: Vec<u32> = (0..2 * PT as u32).map(|i| fam * 50 + i).collect();
                    let tail_pages = rng.next_below(3) as usize;
                    for t in 0..tail_pages * PT {
                        p.push(1000 + fam * 7 + rng.next_below(2) as u32 * 31 + t as u32 % 2);
                    }
                    let m = c.match_prefix(&p);
                    next_seq += 1;
                    if pool.register_with_prefix(next_seq, &m.pages, p.len()).is_ok() {
                        c.insert(&p, &mut pool, next_seq);
                        pool.release(next_seq).unwrap();
                    }
                }
                2 => {
                    let _ = c.evict_one_node(&mut pool);
                    let _ = c.take_dropped_extents(); // extents die with the fake tier
                }
                3 => {
                    if let Some((_, id)) = c.coldest_demotable(&pool) {
                        if c.demote_node(id, &mut pool, &mut |b| tier.write(b)).is_some() {
                            disk_nodes.push(id);
                        }
                    }
                }
                _ => {
                    if !disk_nodes.is_empty() {
                        let i = rng.next_below(disk_nodes.len() as u64) as usize;
                        let id = disk_nodes.swap_remove(i);
                        // May fail (node since evicted, id reused) — the
                        // tree rejects it without side effects.
                        let _ = c.promote_node(id, &mut pool, &mut |e, buf| tier.read(e, buf));
                    }
                }
            }
            for ev in c.take_dir_events() {
                dir.apply(0, M, &ev);
            }
            check(&c, &dir, trial);
        }
        // Drain the tree completely: every advertisement must retract.
        while c.evict_one_node(&mut pool).is_some() {}
        for ev in c.take_dir_events() {
            dir.apply(0, M, &ev);
        }
        assert_eq!(dir.entries(), 0, "trial {trial}: leaked advertisement");
        assert_eq!(pool.used_pages(), 0, "trial {trial}: leaked pages");
    }
}

/// Property: stale directions always fall back cleanly. Random traffic
/// with route hints that are sometimes honest and sometimes fabricated
/// (the advertised entry never existed or was evicted): every request
/// completes with the right number of tokens, and `stale_hits`
/// increments exactly when the hint exceeded what the radix tree
/// actually held.
#[test]
fn prop_stale_directions_fall_back_cleanly() {
    use polarquant::coordinator::request::{GenRequest, Tracked};
    use polarquant::coordinator::scheduler::{PendingPages, Scheduler};
    use polarquant::coordinator::worker::NativeWorker;
    use polarquant::kvcache::pools::{share_pools, PoolSet};
    use polarquant::model::weights::Weights;
    use std::collections::BTreeSet;
    const M: &str = "polarquant-r-offline";
    let cfg = ModelConfig::test();
    let mut rng = Pcg64::new(1013);
    let pools = share_pools(PoolSet::for_model(&cfg, 16, 4096));
    let mut engine = NativeWorker::with_pools(Weights::synthetic(&cfg, 7), pools.clone());
    let mut sched = Scheduler::with_prefix_cache_shared(pools, 4, usize::MAX / 2);
    // Model of the cache: page-aligned heads known to be inserted. The
    // pool is big enough that nothing is ever evicted, so the model is
    // exact and the expected match length is computable.
    let mut cached: BTreeSet<Vec<u32>> = BTreeSet::new();
    for i in 0..40u64 {
        let fam = rng.next_below(4) as u32;
        let pages = 1 + rng.next_below(3) as usize; // 1..=3 full pages
        let prompt: Vec<u32> = (0..pages * 16).map(|x| (fam * 13 + x as u32) % 64).collect();
        let aligned = prompt.len() / 16 * 16;
        let expect_match = (1..=pages)
            .rev()
            .map(|k| prompt[..k * 16].to_vec())
            .find(|head| cached.contains(head))
            .map(|head| head.len())
            .unwrap_or(0);
        let hint = match rng.next_below(3) {
            // Undirected, honestly directed (may be 0), or a possibly
            // stale claim of a full match.
            0 => 0,
            1 => expect_match,
            _ => aligned,
        };
        let mut req = GenRequest::new(i, prompt.clone(), 2);
        req.method = M.into();
        req.route_hint_tokens = hint;
        let gate = sched
            .gate_request(&prompt, 2, M, 0, &PendingPages::new())
            .expect("pool never fills");
        sched.admit_gated(vec![(Tracked::new(req), gate)], &mut engine);
        while !sched.active.is_empty() {
            sched.decode_round(&mut engine);
        }
        for k in 1..=pages {
            cached.insert(prompt[..k * 16].to_vec());
        }
        let ev = sched.take_prefix_events();
        let expected_stale = u64::from(hint > 0 && expect_match < hint);
        assert_eq!(
            ev.stale_hits, expected_stale,
            "request {i}: hint {hint}, cached head {expect_match}"
        );
        assert_eq!(ev.hits + ev.misses, 1, "every request gated and served");
    }
}
