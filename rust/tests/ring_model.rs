//! Exhaustive-interleaving model of the `TraceRing` push/drain protocol
//! (loom-style, self-contained): every schedule of a pushing scheduler
//! thread against a draining reader thread is explored, and in every one
//! the accounting invariant must hold — a push attempt is either resident
//! in the ring, overwritten (counted in `dropped`), or abandoned to lock
//! contention (counted in `contended`). `dropped_spans()` = overwritten +
//! contended, so overwrite-oldest never silently loses a span.
//!
//! The model mirrors `rust/src/obs/ring.rs` semantics exactly:
//! - the pusher uses `try_lock`: if the reader holds the lock, the push is
//!   abandoned and counted, never blocked on (one atomic step — the real
//!   push's critical section is serialized by the mutex);
//! - the reader's critical section spans two model steps (acquire/read,
//!   then release), so pushes can land mid-drain and hit contention;
//! - overflow pops the oldest resident and bumps the same drop counter.
//!
//! A bridge test replays one schedule against the real `WorkerTraces` via
//! its public API to tie the model to the implementation.

use polarquant::obs::ring::WorkerTraces;
use polarquant::obs::span::RequestTrace;

#[derive(Clone)]
struct Model {
    cap: usize,
    /// Resident seqs, oldest first.
    ring: Vec<u64>,
    locked: bool,
    // Ghost state: which attempt went where (sets, so the counters can be
    // checked against actual membership, not just totals).
    overwritten: Vec<u64>,
    contended: Vec<u64>,
    // Thread programs.
    next_push: u64,
    total_pushes: u64,
    /// Reader pc: even = acquire+snapshot, odd = release. One drain = 2 steps.
    reader_pc: usize,
    reader_steps: usize,
    /// Snapshots the reader took while holding the lock.
    snapshots: Vec<Vec<u64>>,
}

impl Model {
    fn new(cap: usize, total_pushes: u64, drains: usize) -> Self {
        Model {
            cap,
            ring: Vec::new(),
            locked: false,
            overwritten: Vec::new(),
            contended: Vec::new(),
            next_push: 0,
            total_pushes,
            reader_pc: 0,
            reader_steps: drains * 2,
            snapshots: Vec::new(),
        }
    }

    fn dropped_spans(&self) -> u64 {
        (self.overwritten.len() + self.contended.len()) as u64
    }

    fn pusher_runnable(&self) -> bool {
        self.next_push < self.total_pushes
    }

    fn reader_runnable(&self) -> bool {
        self.reader_pc < self.reader_steps
    }

    fn step_pusher(&mut self) {
        let seq = self.next_push;
        self.next_push += 1;
        if self.locked {
            // try_lock failure: drop and count, never wait.
            self.contended.push(seq);
            return;
        }
        if self.ring.len() == self.cap {
            let oldest = self.ring.remove(0);
            self.overwritten.push(oldest);
        }
        self.ring.push(seq);
    }

    fn step_reader(&mut self) {
        if self.reader_pc % 2 == 0 {
            // Blocking lock: the pusher's critical section is atomic in
            // this model, so acquisition always succeeds here.
            assert!(!self.locked, "reader is the only blocking locker");
            self.locked = true;
            self.snapshots.push(self.ring.clone());
        } else {
            self.locked = false;
        }
        self.reader_pc += 1;
    }

    fn check_invariants(&self) {
        assert!(self.ring.len() <= self.cap, "ring exceeded capacity");
        // Accounting: every attempted push is exactly one of resident /
        // overwritten / contended.
        let mut accounted: Vec<u64> = self
            .ring
            .iter()
            .chain(self.overwritten.iter())
            .chain(self.contended.iter())
            .copied()
            .collect();
        accounted.sort_unstable();
        let expected: Vec<u64> = (0..self.next_push).collect();
        assert_eq!(accounted, expected, "a span was lost or double-counted");
        assert_eq!(
            self.next_push,
            self.ring.len() as u64 + self.dropped_spans(),
            "dropped_spans does not cover the non-resident attempts"
        );
        // Residents are the most recent successful pushes, in order.
        assert!(self.ring.windows(2).all(|w| w[0] < w[1]), "ring order scrambled");
    }

    fn check_terminal(&self) {
        self.check_invariants();
        assert!(!self.locked, "reader finished while holding the lock");
        // Every snapshot the reader took is a plausible ring state:
        // bounded, ordered, and of seqs that had been pushed by then.
        for snap in &self.snapshots {
            assert!(snap.len() <= self.cap);
            assert!(snap.windows(2).all(|w| w[0] < w[1]));
        }
    }
}

/// DFS over every interleaving; returns the number of terminal schedules.
fn explore(m: &Model) -> u64 {
    m.check_invariants();
    let p = m.pusher_runnable();
    let r = m.reader_runnable();
    if !p && !r {
        m.check_terminal();
        return 1;
    }
    let mut leaves = 0;
    if p {
        let mut next = m.clone();
        next.step_pusher();
        leaves += explore(&next);
    }
    if r {
        let mut next = m.clone();
        next.step_reader();
        leaves += explore(&next);
    }
    leaves
}

#[test]
fn no_schedule_loses_a_span() {
    // 6 pushes vs 3 full drain cycles over a cap-2 ring: C(12,6) = 924
    // schedules, all explored.
    let leaves = explore(&Model::new(2, 6, 3));
    assert_eq!(leaves, 924, "exhaustiveness check: C(12,6) interleavings");
}

#[test]
fn contention_only_happens_mid_drain() {
    // With no reader at all, nothing can be contended and exactly
    // (pushes - cap) spans are overwritten.
    let mut m = Model::new(3, 8, 0);
    while m.pusher_runnable() {
        m.step_pusher();
    }
    m.check_terminal();
    assert!(m.contended.is_empty());
    assert_eq!(m.overwritten.len(), 5);
    assert_eq!(m.dropped_spans(), 5);
}

#[test]
fn larger_ring_and_more_drains_still_account_for_every_span() {
    let leaves = explore(&Model::new(1, 5, 2));
    assert_eq!(leaves, 126, "C(9,5) interleavings");
    let leaves = explore(&Model::new(4, 4, 4));
    assert_eq!(leaves, 495, "C(12,4) interleavings");
}

fn trace(id: u64) -> RequestTrace {
    RequestTrace {
        id,
        worker: 0,
        method: "exact".into(),
        route_kind: "local",
        route_hint_tokens: 0,
        prompt_tokens: 1,
        reused_tokens: 0,
        promoted_pages: 0,
        gen_tokens: 1,
        decode_rounds: 1,
        start_us: id * 10,
        total_s: 0.001,
        spans: Vec::new(),
    }
}

#[test]
fn model_agrees_with_real_worker_traces_on_sequential_schedules() {
    // Replay the all-pushes-then-drain schedule against the real ring via
    // its public API and compare the accounting the model predicts.
    for (cap, pushes) in [(4usize, 7u64), (2, 2), (1, 6), (8, 3)] {
        let mut m = Model::new(cap, pushes, 1);
        while m.pusher_runnable() {
            m.step_pusher();
        }
        m.step_reader();
        m.step_reader();
        m.check_terminal();

        let wt = WorkerTraces::local(cap);
        for i in 0..pushes {
            wt.push(trace(i));
        }
        let (batch, _mark) = wt.since(0);
        assert_eq!(wt.dropped_spans(), m.dropped_spans(), "cap={cap} pushes={pushes}");
        let got: Vec<u64> = batch.iter().map(|t| t.id).collect();
        assert_eq!(got, m.snapshots[0], "cap={cap} pushes={pushes}");
    }
}
