//! End-to-end serving integration: the full coordinator under concurrent
//! load, across cache methods, with failure injection (pool exhaustion,
//! oversized requests) — the L3 system tests.

use polarquant::coordinator::batcher::BatchPolicy;
use polarquant::coordinator::request::GenRequest;
use polarquant::coordinator::server::{Server, ServerConfig};
use polarquant::kvcache::tier::temp_spill_dir;
use polarquant::model::config::ModelConfig;
use polarquant::util::json::Json;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn server(workers: usize, pool_tokens: usize) -> Server {
    Server::start(ServerConfig {
        model: ModelConfig::test(),
        seed: 1,
        workers,
        batch: BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() },
        pool_tokens,
        max_active: 4,
        prefix_cache: true,
        ..Default::default()
    })
}

#[test]
fn mixed_methods_under_load() {
    let s = server(2, 1 << 14);
    let methods = [
        "exact",
        "fp16",
        "kivi",
        "snapkv",
        "streamingllm",
        "polarquant",
        "polarquant-r-offline",
        "polarquant-r-online",
        "qjl",
        "headkv",
        "pyramidkv",
    ];
    let n = methods.len() * 2;
    for i in 0..n {
        let mut req = GenRequest::new(0, (0..32).map(|x| (x * 7 + i as u32) % 64).collect(), 4);
        req.method = methods[i % methods.len()].into();
        req.session = Some(format!("sess-{}", i % 3));
        s.submit(req);
    }
    let mut done = 0;
    while done < n {
        let resp = s.recv_timeout(Duration::from_secs(120)).expect("complete");
        assert_eq!(resp.tokens.len(), 4, "method {}", resp.method);
        assert!(resp.compression_ratio > 0.0);
        done += 1;
    }
    assert_eq!(s.metrics.requests_done.load(Ordering::Relaxed) as usize, n);
    assert!(s.metrics.throughput() > 0.0);
    s.shutdown();
}

#[test]
fn page_codecs_serve_end_to_end() {
    // Every page-native codec (polarquant variants, exact f32, fp16,
    // kivi) serves through the pool substrate: prompt codes written to
    // page slots at prefill, decode scoring straight off the pages, and
    // repeat prompts reusing the encoded pages zero-copy.
    let s = server(1, 1 << 14);
    let prompt: Vec<u32> = (0..40).map(|x| (x * 3 + 1) % 64).collect();
    for method in ["polarquant", "polarquant-r-offline", "exact", "fp16", "kivi"] {
        let mut req = GenRequest::new(0, prompt.clone(), 4);
        req.method = method.into();
        let first = s.generate_blocking(req, Duration::from_secs(60)).expect("cold");
        assert_eq!(first.tokens.len(), 4, "{method}");
        assert_eq!(first.reused_tokens, 0, "{method}: cold");
        assert!(first.cache_bytes > 0, "{method}");
        // Second sighting reuses this codec's own encoded pages — the
        // 40-token prompt has 2 full 16-token pages to share.
        let mut req = GenRequest::new(0, prompt.clone(), 4);
        req.method = method.into();
        let again = s.generate_blocking(req, Duration::from_secs(60)).expect("warm");
        assert_eq!(again.reused_tokens, 32, "{method}: page-aligned reuse");
        assert_eq!(again.tokens.len(), 4, "{method}");
    }
    s.shutdown();
}

#[test]
fn deterministic_generation_across_replicas() {
    // Same prompt + greedy sampling must produce identical tokens on any
    // worker (weights seeded identically), cold or prefix-warm — the
    // router can spread freely. Pinned to the lossless `exact` codec:
    // warm requests replay the codec's own pool pages, so for lossy
    // codecs a hit reproduces the quantized cache (tolerance-tested in
    // codec_parity), while `exact` is bit-identical by construction.
    let s = server(3, 1 << 14);
    let prompt: Vec<u32> = (0..24).map(|x| x % 64).collect();
    let mut outputs = Vec::new();
    for _ in 0..6 {
        let mut req = GenRequest::new(0, prompt.clone(), 5);
        req.method = "exact".into();
        let resp = s.generate_blocking(req, Duration::from_secs(60)).unwrap();
        outputs.push(resp.tokens);
    }
    for o in &outputs[1..] {
        assert_eq!(o, &outputs[0]);
    }
    s.shutdown();
}

#[test]
fn quantized_methods_report_smaller_caches() {
    let s = server(1, 1 << 14);
    let prompt: Vec<u32> = (0..48).map(|x| (x * 3) % 64).collect();
    let get = |method: &str| {
        let mut req = GenRequest::new(0, prompt.clone(), 3);
        req.method = method.into();
        s.generate_blocking(req, Duration::from_secs(60)).unwrap()
    };
    let exact = get("exact");
    let polar = get("polarquant-r-offline");
    assert!(
        polar.cache_bytes * 2 < exact.cache_bytes,
        "polar {} vs exact {}",
        polar.cache_bytes,
        exact.cache_bytes
    );
    assert!(polar.compression_ratio < 0.5);
    s.shutdown();
}

#[test]
fn pool_exhaustion_rejects_cleanly_then_recovers() {
    let s = server(1, 256); // tiny pool: 256 tokens
    // This request fits.
    let ok = s
        .generate_blocking(GenRequest::new(0, vec![1; 64], 3), Duration::from_secs(60))
        .unwrap();
    assert_eq!(ok.tokens.len(), 3);
    // This one cannot ever fit → rejected, not hung.
    let rejected = s
        .generate_blocking(GenRequest::new(0, vec![1; 1024], 3), Duration::from_secs(60))
        .unwrap();
    assert!(rejected.tokens.is_empty());
    // And the server still works afterwards.
    let again = s
        .generate_blocking(GenRequest::new(0, vec![1; 64], 3), Duration::from_secs(60))
        .unwrap();
    assert_eq!(again.tokens.len(), 3);
    assert_eq!(s.metrics.requests_rejected.load(Ordering::Relaxed), 1);
    s.shutdown();
}

/// Worker count for the multi-worker routing comparison; the CI
/// `multi-worker-e2e` job pins it to 4 via `PQ_E2E_WORKERS`.
fn e2e_workers() -> usize {
    std::env::var("PQ_E2E_WORKERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4)
        .max(2)
}

#[test]
fn directed_routing_beats_round_robin_for_anonymous_traffic() {
    // Anonymous mixed-prefix traffic: `workers + 1` prompt families
    // sharing 64-token heads (4 full pages), distinct tails, no session
    // keys, submitted in the same order to both configurations.
    // Round-robin scatters each family across replicas (the family
    // count is coprime with the worker count, so a family never camps
    // on one worker by accident) and re-prefills cold; directed routing
    // lands repeats on the replica that already holds the pages.
    let families = e2e_workers() as u32 + 1;
    let run = |directed: bool| {
        let s = Server::start(ServerConfig {
            model: ModelConfig::test(),
            seed: 1,
            workers: e2e_workers(),
            batch: BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() },
            pool_tokens: 1 << 14,
            max_active: 4,
            prefix_cache: true,
            prefix_routing: directed,
            round_robin: !directed,
            ..Default::default()
        });
        let mut reused = 0usize;
        for round in 0..4u32 {
            for fam in 0..families {
                let mut p: Vec<u32> = (0..64).map(|x| (x * 7 + fam * 17 + 3) % 64).collect();
                p.extend((0..8).map(|x| (x * 5 + round) % 64));
                let resp = s
                    .generate_blocking(GenRequest::new(0, p, 4), Duration::from_secs(120))
                    .expect("response");
                assert_eq!(resp.tokens.len(), 4);
                reused += resp.reused_tokens;
            }
        }
        let snap = Json::parse(&s.metrics.snapshot().encode()).unwrap();
        let get = |k: &str| snap.path(k).unwrap().as_f64().unwrap();
        let stats = (
            get("prefix_cache.hits"),
            reused,
            get("prefix_routing.directed"),
            get("prefix_routing.stale_hits"),
        );
        s.shutdown();
        stats
    };
    let (hits_rr, reused_rr, directed_rr, _) = run(false);
    let (hits_dir, reused_dir, directed_n, stale) = run(true);
    assert_eq!(directed_rr, 0.0, "no directory when routing is off");
    assert!(directed_n > 0.0, "directed count must be positive: {directed_n}");
    assert!(
        hits_dir > hits_rr,
        "directed hit count must beat round-robin: {hits_dir} vs {hits_rr}"
    );
    assert!(
        reused_dir > reused_rr,
        "directed reuse must beat round-robin: {reused_dir} vs {reused_rr}"
    );
    assert_eq!(stale, 0.0, "sequential blocking traffic cannot go stale");
}

#[test]
fn ttft_less_than_total_and_metrics_consistent() {
    let s = server(1, 1 << 14);
    let resp = s
        .generate_blocking(GenRequest::new(0, vec![5; 40], 6), Duration::from_secs(60))
        .unwrap();
    assert!(resp.timing.ttft_s <= resp.timing.total_s + 1e-9);
    assert!(resp.timing.prefill_s > 0.0);
    assert!(resp.timing.decode_s > 0.0);
    let snap = s.metrics.snapshot();
    assert_eq!(snap.path("requests.done").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(snap.path("tokens.generated").unwrap().as_f64().unwrap(), 6.0);
    s.shutdown();
}

#[test]
fn trace_dir_emits_wellformed_chrome_json() {
    // Every worker writes `trace-worker<idx>.json` under --trace-dir; each
    // file must be a well-formed JSON array of Chrome complete-events, and
    // every completed request must leave a closed span chain whose
    // top-level phases (queue/prefill/decode/finish) tile `total_s`.
    let dir = temp_spill_dir("trace-e2e");
    let workers = e2e_workers();
    let s = Server::start(ServerConfig {
        model: ModelConfig::test(),
        seed: 1,
        workers,
        batch: BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() },
        pool_tokens: 1 << 14,
        max_active: 4,
        prefix_cache: true,
        trace_dir: Some(dir.clone()),
        ..Default::default()
    });
    let n = 8usize;
    for i in 0..n {
        let mut req = GenRequest::new(0, (0..32).map(|x| (x * 7 + i as u32) % 64).collect(), 4);
        req.session = Some(format!("trace-{i}"));
        s.submit(req);
    }
    for _ in 0..n {
        let resp = s.recv_timeout(Duration::from_secs(120)).expect("complete");
        assert_eq!(resp.tokens.len(), 4);
    }
    s.shutdown(); // the final flush drains every worker's ring into its file
    let mut seen_ids = std::collections::BTreeSet::new();
    for w in 0..workers {
        let path = dir.join(format!("trace-worker{w}.json"));
        let text = std::fs::read_to_string(&path).expect("per-worker trace file");
        let events = Json::parse(&text).expect("well-formed JSON");
        // (chain-summed non-nested durations, total_s) per request lane.
        let mut chains: std::collections::BTreeMap<u64, (f64, f64)> = Default::default();
        for e in events.as_arr().expect("trace-event array") {
            assert_eq!(e.path("ph").unwrap().as_str().unwrap(), "X");
            assert_eq!(e.path("pid").unwrap().as_f64().unwrap() as usize, w);
            let tid = e.path("tid").unwrap().as_f64().unwrap() as u64;
            if tid == 0 {
                continue; // scheduler tick lane
            }
            let name = e.path("name").unwrap().as_str().unwrap();
            let slot = chains.entry(tid).or_insert((0.0, 0.0));
            slot.1 = e.path("args.total_s").unwrap().as_f64().unwrap();
            // gate nests inside queue and promote inside gate; route
            // precedes arrival. The rest tiles the request wall-clock.
            if !matches!(name, "route" | "gate" | "promote") {
                slot.0 += e.path("dur").unwrap().as_f64().unwrap() * 1e-6;
            }
        }
        for (tid, (sum, total)) in &chains {
            seen_ids.insert(tid - 1);
            assert!(
                (sum - total).abs() <= 0.05 * total + 20e-6,
                "worker {w} request {}: span chain {sum:.6}s vs total {total:.6}s",
                tid - 1
            );
        }
    }
    assert_eq!(seen_ids.len(), n, "every request left a trace: {seen_ids:?}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trace_promote_span_matches_tier_stall() {
    // A promoted prefix hit must carry a `promote` span whose duration is
    // exactly the disk stall the tier metrics account for (same timer,
    // one promotion in the whole run).
    let s = Server::start(ServerConfig {
        model: ModelConfig::test(),
        seed: 3,
        workers: 1,
        batch: BatchPolicy { max_wait: Duration::from_millis(1), ..Default::default() },
        pool_tokens: 128, // 8 pages of 16 tokens — tight on purpose
        max_active: 2,
        prefix_cache: true,
        spill_dir: Some(temp_spill_dir("trace-promote")),
        ..Default::default()
    });
    let a: Vec<u32> = (0..48).map(|x| (x * 5 + 2) % 64).collect();
    let b: Vec<u32> = (0..80).map(|x| (x * 3 + 1) % 64).collect();
    let ask =
        |p: Vec<u32>| s.generate_blocking(GenRequest::new(0, p, 4), Duration::from_secs(60));
    assert_eq!(ask(a.clone()).expect("a cold").reused_tokens, 0);
    ask(b).expect("b evicts a's pages to disk");
    let warm = ask(a).expect("a warm");
    assert_eq!(warm.reused_tokens, 47, "disk-warmed hit");
    let snap = s.metrics.snapshot();
    let stall = snap.path("kv_tier.promote_stall_us").unwrap().as_f64().unwrap();
    assert!(stall > 0.0, "promotion reads disk; the stall must be measurable");
    let traces = s.trace_json(8);
    let tr = traces
        .path("traces")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|t| t.path("id").unwrap().as_f64().unwrap() as u64 == warm.id)
        .expect("warm request's trace");
    assert!(tr.path("promoted_pages").unwrap().as_f64().unwrap() >= 3.0);
    let spans = tr.path("spans").unwrap().as_arr().unwrap();
    let promote = spans
        .iter()
        .find(|sp| sp.path("name").unwrap().as_str().unwrap() == "promote")
        .expect("promote span on the disk-warmed trace");
    assert_eq!(
        promote.path("dur_us").unwrap().as_f64().unwrap(),
        stall,
        "the promote span is the tier's promote stall"
    );
    assert!((warm.timing.promote_s - stall * 1e-6).abs() < 1e-9, "Timing agrees");
    s.shutdown();
}

#[test]
fn trace_directed_request_carries_route_hint() {
    // Anonymous repeat of a published prefix: the router directs it and
    // stamps the advertised depth, and both survive into the trace.
    let s = server(2, 1 << 14);
    let prompt: Vec<u32> = (0..48).map(|x| (x * 5 + 2) % 64).collect();
    let cold = s
        .generate_blocking(GenRequest::new(0, prompt.clone(), 4), Duration::from_secs(60))
        .expect("cold");
    assert_eq!(cold.reused_tokens, 0);
    let warm = s
        .generate_blocking(GenRequest::new(0, prompt, 4), Duration::from_secs(60))
        .expect("warm");
    assert_eq!(warm.reused_tokens, 47, "directed onto the warm replica");
    let traces = s.trace_json(8);
    let traces = traces.path("traces").unwrap().as_arr().unwrap();
    assert_eq!(traces.len(), 2);
    let by_id = |id: u64| {
        traces
            .iter()
            .find(|t| t.path("id").unwrap().as_f64().unwrap() as u64 == id)
            .expect("trace present")
    };
    let warm_tr = by_id(warm.id);
    assert_eq!(warm_tr.path("route_kind").unwrap().as_str().unwrap(), "directed");
    // 3 full 16-token pages advertised → the hint covers the whole prompt.
    assert_eq!(warm_tr.path("route_hint_tokens").unwrap().as_f64().unwrap(), 48.0);
    assert_eq!(warm_tr.path("reused_tokens").unwrap().as_f64().unwrap(), 47.0);
    let cold_tr = by_id(cold.id);
    assert_eq!(cold_tr.path("route_kind").unwrap().as_str().unwrap(), "fallback");
    assert_eq!(cold_tr.path("route_hint_tokens").unwrap().as_f64().unwrap(), 0.0);
    s.shutdown();
}
