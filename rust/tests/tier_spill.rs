//! Tiered KV page store invariants: demote→promote round-trips are
//! byte-identical for every registered page codec, spilled prefixes are
//! served back bit-identically after promotion, and watermark demotion
//! keeps RAM occupancy bounded.
//!
//! The RAM high-water mark is overridable via `PQ_TIER_HIGH_WATER`
//! (fraction; low water is half of it) — CI's `tier-spill` job sets a
//! deliberately tiny value so demotion fires on every test. Spill dirs
//! are per-process tempdirs removed by `TierManager` on drop; no
//! cleanup is needed.

use polarquant::coordinator::request::{GenRequest, GenResponse, Tracked};
use polarquant::coordinator::scheduler::Scheduler;
use polarquant::coordinator::worker::NativeWorker;
use polarquant::kvcache::codec::PAGE_CODEC_METHODS;
use polarquant::kvcache::pools::{share_pools, PoolSet};
use polarquant::kvcache::tier::{temp_spill_dir, TierConfig, TierManager};
use polarquant::model::config::ModelConfig;
use polarquant::model::weights::Weights;
use polarquant::prefix::PrefixCacheSet;
use polarquant::util::rng::{Pcg64, Rng};

const PT: usize = 4;

fn watermarks() -> (f64, f64) {
    let high: f64 = std::env::var("PQ_TIER_HIGH_WATER")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.5);
    (high, high / 2.0)
}

fn tier(tag: &str) -> TierManager {
    let (high, low) = watermarks();
    let mut cfg = TierConfig::new(temp_spill_dir(tag));
    cfg.high_water = high;
    cfg.low_water = low;
    TierManager::new(cfg).unwrap()
}

/// Deterministic byte pattern for the token slot at position `t` of a
/// prompt: a hash of the method and the token prefix up to and
/// including `t`. Two sequences agree on a slot's pattern exactly when
/// they agree on the whole prefix — the same condition under which the
/// radix tree shares the page — so the model stays consistent under
/// arbitrary sharing.
fn slot_pattern(method: &str, prefix: &[u32], slot_bytes: usize) -> Vec<u8> {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in method.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x1000_0000_01b3);
    }
    for &t in prefix {
        h = (h ^ (t as u64 + 1)).wrapping_mul(0x1000_0000_01b3);
    }
    (0..slot_bytes)
        .map(|i| (h.wrapping_mul(2 * i as u64 + 1) >> 24) as u8)
        .collect()
}

fn expected_page(method: &str, prompt: &[u32], page_idx: usize, slot_bytes: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(PT * slot_bytes);
    for t in page_idx * PT..(page_idx + 1) * PT {
        out.extend(slot_pattern(method, &prompt[..t + 1], slot_bytes));
    }
    out
}

#[test]
fn demote_promote_roundtrip_is_byte_identical_for_every_codec() {
    let cfg = ModelConfig::test();
    // Every registry family, plus a parameterized adaptive spec: its
    // custom table layout gets its own pool at its own slot width, and
    // the tier's pure byte-copy path must be layout-agnostic.
    for method in PAGE_CODEC_METHODS.into_iter().chain(["adaptive:budget=3.25"]) {
        let mut pools = PoolSet::for_model(&cfg, PT, 256);
        let mut pc = PrefixCacheSet::new(PT, usize::MAX);
        let mut t = tier(&format!("roundtrip-{method}"));
        let slot_bytes = pools.token_bytes_for(method);
        let prompt: Vec<u32> = (0..12).map(|i| (i * 7 + 1) % 64).collect();
        pools.pool_mut(method).register(1, 12).unwrap();
        for i in 0..12 {
            pools.pool_mut(method).token_slot_mut(1, i).unwrap().copy_from_slice(
                &slot_pattern(method, &prompt[..i + 1], slot_bytes),
            );
        }
        let node = pc.insert(method, &prompt, pools.pool_mut(method), 1).unwrap();
        pools.release(method, 1).unwrap();

        let pool = pools.pool_mut(method);
        let (_, victim) = pc.coldest_demotable(method, pool).expect("cold leaf");
        assert_eq!(victim, node);
        let n = pc
            .demote_node(method, victim, pool, &mut |b| t.spill_page(method, b))
            .expect("demoted");
        assert_eq!(n, 3, "{method}: all three pages spilled");
        assert_eq!(pool.used_pages(), 0, "{method}: RAM fully released");
        assert_eq!(t.disk_bytes(), 3 * pool.page_bytes(), "{method}: disk priced per codec");

        let exts = pc
            .promote_node(method, victim, pool, &mut |e, buf| t.promote_page(method, e, buf))
            .expect("promoted");
        for e in exts {
            t.free_promoted(method, e);
        }
        assert_eq!(t.disk_bytes(), 0);
        let m = pc.match_prefix(method, &prompt);
        assert_eq!(m.tokens, 12, "{method}: full match after promotion");
        let pool = pools.pool(method).unwrap();
        for (i, &pg) in m.pages.iter().enumerate() {
            assert_eq!(
                pool.page_slice(pg),
                &expected_page(method, &prompt, i, slot_bytes)[..],
                "{method}: page {i} byte-identical after the disk round-trip"
            );
        }
    }
}

/// Random interleavings of admit (append/retain via
/// `register_with_prefix` + slot writes + insert), release, demote,
/// promote, and true eviction — after every round each cached prompt's
/// matchable pages must hold exactly the bytes written at encode time,
/// and disk accounting must equal the tree's spilled page count.
#[test]
fn prop_spill_roundtrips_survive_random_interleavings() {
    let cfg = ModelConfig::test();
    for method in PAGE_CODEC_METHODS {
        let mut pools = PoolSet::for_model(&cfg, PT, 128); // 32 pages
        let mut pc = PrefixCacheSet::new(PT, usize::MAX);
        let mut t = tier(&format!("prop-{method}"));
        let slot_bytes = pools.token_bytes_for(method);
        let mut rng = Pcg64::new(0xC0FFEE ^ method.len() as u64);
        let mut next_seq: u64 = 0;
        let mut live: Vec<(u64, usize)> = Vec::new(); // (seq, tokens)
        let mut prompts: Vec<Vec<u32>> = Vec::new();

        // Prefix-sharing families: prompts of one family agree on every
        // position, so shorter members are prefixes of longer ones.
        let mut mk_prompt = |rng: &mut Pcg64| -> Vec<u32> {
            let fam = rng.next_below(3) as u32;
            let len = (1 + rng.next_below(4) as usize) * PT;
            (0..len).map(|i| (fam * 31 + i as u32 * 5 + 1) % 64).collect()
        };

        for round in 0..80 {
            match rng.next_below(10) {
                0..=4 => {
                    // Admit: match (promoting any spilled path nodes the
                    // way the scheduler gate does), share, write, insert.
                    let prompt = mk_prompt(&mut rng);
                    let mut m = pc.match_prefix(method, &prompt);
                    if !m.disk.is_empty() {
                        let pool = pools.pool_mut(method);
                        for id in m.disk.clone() {
                            let exts = pc.promote_node(method, id, pool, &mut |e, buf| {
                                t.promote_page(method, e, buf)
                            });
                            match exts {
                                Some(exts) => {
                                    for e in exts {
                                        t.free_promoted(method, e);
                                    }
                                }
                                None => break, // pool full: truncated match
                            }
                        }
                        m = pc.match_prefix(method, &prompt);
                    }
                    next_seq += 1;
                    let seq = next_seq;
                    let pool = pools.pool_mut(method);
                    if pool.register_with_prefix(seq, &m.pages, prompt.len()).is_err() {
                        continue; // pool too full this round — fine
                    }
                    for i in m.tokens..prompt.len() {
                        pool.token_slot_mut(seq, i).unwrap().copy_from_slice(&slot_pattern(
                            method,
                            &prompt[..i + 1],
                            slot_bytes,
                        ));
                    }
                    pc.insert(method, &prompt, pool, seq);
                    if !prompts.contains(&prompt) {
                        prompts.push(prompt);
                    }
                    if rng.next_below(2) == 0 {
                        pools.release(method, seq).unwrap();
                    } else {
                        live.push((seq, 0));
                    }
                }
                5 => {
                    if let Some(i) = (!live.is_empty()).then(|| rng.next_below(live.len() as u64)) {
                        let (seq, _) = live.swap_remove(i as usize);
                        pools.release(method, seq).unwrap();
                    }
                }
                6..=7 => {
                    let pool = pools.pool_mut(method);
                    if let Some((_, id)) = pc.coldest_demotable(method, pool) {
                        pc.demote_node(method, id, pool, &mut |b| t.spill_page(method, b));
                    }
                }
                8 => {
                    // Append into a live sequence: boundary allocations
                    // and COW splits must never corrupt cached pages.
                    if let Some(i) = (!live.is_empty()).then(|| rng.next_below(live.len() as u64)) {
                        let (seq, extra) = &mut live[i as usize];
                        if pools.pool_mut(method).append_token(*seq).is_ok() {
                            *extra += 1;
                        }
                    }
                }
                _ => {
                    let pool = pools.pool_mut(method);
                    pc.evict_one_node(method, pool);
                    for e in pc.take_dropped_extents(method) {
                        t.discard(method, e);
                    }
                }
            }

            // Invariants, every round.
            assert_eq!(
                t.disk_bytes(),
                pc.disk_pages() * pools.pool(method).unwrap().page_bytes(),
                "round {round}: disk accounting tracks spilled pages exactly"
            );
            for prompt in &prompts {
                let m = pc.match_prefix(method, prompt);
                let pool = pools.pool(method).unwrap();
                for (i, &pg) in m.pages.iter().enumerate() {
                    assert_eq!(
                        pool.page_slice(pg),
                        &expected_page(method, prompt, i, slot_bytes)[..],
                        "round {round}: {method} prompt page {i} corrupted"
                    );
                }
            }
        }
        // Drain: retire the remaining live sequences, promote everything
        // back, and verify the full working set.
        for (seq, _) in live.drain(..) {
            pools.release(method, seq).unwrap();
        }
        loop {
            let mut promoted_any = false;
            for prompt in prompts.clone() {
                let m = pc.match_prefix(method, &prompt);
                let pool = pools.pool_mut(method);
                for id in m.disk {
                    let read = &mut |e, buf: &mut [u8]| t.promote_page(method, e, buf);
                    if let Some(exts) = pc.promote_node(method, id, pool, read) {
                        for e in exts {
                            t.free_promoted(method, e);
                        }
                        promoted_any = true;
                    }
                }
            }
            if !promoted_any {
                break;
            }
        }
        for prompt in &prompts {
            let m = pc.match_prefix(method, prompt);
            assert_eq!(m.disk_tokens, 0, "everything promotable was promoted");
            let pool = pools.pool(method).unwrap();
            for (i, &pg) in m.pages.iter().enumerate() {
                assert_eq!(
                    pool.page_slice(pg),
                    &expected_page(method, prompt, i, slot_bytes)[..],
                    "final: {method} prompt page {i}"
                );
            }
        }
    }
}

fn run_to_completion(s: &mut Scheduler, e: &mut NativeWorker) -> Vec<GenResponse> {
    let mut done = Vec::new();
    while !s.active.is_empty() {
        done.extend(s.decode_round(e).finished);
    }
    done
}

/// Warm-hit generation for `method`: request once cold, optionally
/// force the cached prefix through a disk round-trip, request again.
/// Returns (second response, promoted_pages, reused_tokens).
fn warm_hit(
    cfg: &ModelConfig,
    method: &str,
    prompt: &[u32],
    spill: bool,
) -> (Vec<u32>, u64, usize) {
    // 4 pool pages of 16 tokens: the 48-token prompt + generation room
    // exactly fits, and its 3 cached pages sit far above any high-water
    // fraction, so `run_demotion` always spills them when a tier is on.
    let pools = share_pools(PoolSet::for_model(cfg, 16, 64));
    let mut engine = NativeWorker::with_pools(Weights::synthetic(cfg, 5), pools.clone());
    let mut sched = Scheduler::with_prefix_cache_shared(pools, 4, 1 << 30);
    if spill {
        sched.set_tier(tier(&format!("e2e-{method}")));
    }
    let mk = |id: u64| {
        let mut r = GenRequest::new(id, prompt.to_vec(), 4);
        r.method = method.into();
        Tracked::new(r)
    };
    assert_eq!(sched.admit(vec![mk(1)], &mut engine), 1, "{method}: cold admit");
    run_to_completion(&mut sched, &mut engine);
    if spill {
        sched.run_demotion();
        let pc = sched.prefix.as_ref().unwrap();
        assert!(pc.disk_pages() >= 3, "{method}: prefix spilled before the re-request");
        assert_eq!(pc.cached_pages(), 0);
    }
    assert_eq!(sched.admit(vec![mk(2)], &mut engine), 1, "{method}: warm admit");
    let resp = run_to_completion(&mut sched, &mut engine).remove(0);
    let promoted = sched.take_tier_events().promoted_pages;
    (resp.tokens, promoted, resp.reused_tokens)
}

/// The end-to-end acceptance invariant: a prefix hit served from
/// promoted (disk-warmed) pages generates output identical to a
/// RAM-warm hit — bit-identical page bytes make this hold for every
/// page codec, and for `exact` the warm path is itself pinned
/// bit-identical to a cold prefill by `codec_parity`.
#[test]
fn promoted_hit_generates_identically_to_ram_warm_hit() {
    let cfg = ModelConfig::test();
    let prompt: Vec<u32> = (0..48).map(|i| (i * 11 + 3) % 64).collect();
    for method in PAGE_CODEC_METHODS {
        let (ram_tokens, ram_promoted, ram_reused) = warm_hit(&cfg, method, &prompt, false);
        let (disk_tokens, promoted, disk_reused) = warm_hit(&cfg, method, &prompt, true);
        assert_eq!(ram_promoted, 0);
        assert!(promoted >= 3, "{method}: hit was served from promoted pages");
        assert_eq!(ram_reused, 47, "{method}: RAM-warm hit reuses the clamped prefix");
        assert_eq!(disk_reused, ram_reused, "{method}: same reuse after the disk round-trip");
        assert_eq!(disk_tokens, ram_tokens, "{method}: generations identical");
    }
}

/// Acceptance: after a demotion pass runs, RAM occupancy sits at or
/// under the high-water mark (the pass drains to low water, which is
/// stricter), while every spilled prompt stays matchable.
#[test]
fn ram_occupancy_bounded_by_watermark_after_demotion() {
    let cfg = ModelConfig::test();
    let (high, _) = watermarks();
    let pools = share_pools(PoolSet::for_model(&cfg, 4, 64)); // 16 pages
    let mut engine = NativeWorker::with_pools(Weights::synthetic(&cfg, 5), pools.clone());
    let mut sched = Scheduler::with_prefix_cache_shared(pools.clone(), 4, 1 << 30);
    sched.set_tier(tier("watermark"));
    let method = "polarquant-r-offline";
    let mut prompts = Vec::new();
    for i in 0..6u64 {
        let prompt: Vec<u32> = (0..8).map(|x| (x * 3 + i as u32 * 17 + 1) % 64).collect();
        let mut r = GenRequest::new(i + 1, prompt.clone(), 4);
        r.method = method.into();
        // `admit` runs a demotion pass after every round; completed
        // prompts from earlier rounds are the demotable mass.
        sched.admit(vec![Tracked::new(r)], &mut engine);
        run_to_completion(&mut sched, &mut engine);
        prompts.push(prompt);
    }
    sched.run_demotion();
    let (used, num) = {
        let pools = pools.lock().unwrap();
        let p = pools.pool(method).unwrap();
        (p.used_pages(), 16usize)
    };
    assert!(
        used as f64 <= (high * num as f64).max(1.0),
        "occupancy {used}/{num} exceeds the high-water mark {high}"
    );
    let ev = sched.take_tier_events();
    assert!(ev.demoted_pages > 0, "pressure actually demoted pages");
    assert_eq!(ev.true_evictions, 0, "nothing was dropped for good");
    let pc = sched.prefix.as_mut().unwrap();
    for (i, p) in prompts.iter().enumerate() {
        let m = pc.match_prefix(method, p);
        assert_eq!(m.tokens + m.disk_tokens, 8, "prompt {i} still matchable");
    }
}
