//! Clean hatch fixture: a reasoned line-level hatch suppresses the
//! deliberate cross-unit comparison and is enumerated in the report.

pub fn hatched(free_bytes: usize, want_pages: usize) -> bool {
    // analyze: allow(unit_mix, "fixture: deliberate cross-unit comparison")
    want_pages < free_bytes
}
