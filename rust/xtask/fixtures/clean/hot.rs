//! Clean hot-path fixture: the seed only try-locks (drop-on-contention)
//! and the helper works in place without allocating or panicking.

use std::sync::Mutex;

pub struct Ring {
    pub slots: Mutex<Vec<u32>>,
}

pub fn hot_seed(r: &Ring, xs: &[u32]) -> u32 {
    let total = helper(xs);
    match r.slots.try_lock() {
        Ok(guard) => total + guard.len() as u32,
        Err(_) => total,
    }
}

fn helper(xs: &[u32]) -> u32 {
    let mut acc = 0;
    for &x in xs {
        acc += x;
    }
    acc
}
