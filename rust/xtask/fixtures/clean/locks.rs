//! Clean lock fixture: ascending tier order, and a higher-tier guard
//! explicitly dropped before a lower-tier acquisition.

use std::sync::Mutex;

pub struct State {
    pub pools: Mutex<u32>,
    pub tables: Mutex<u32>,
}

impl State {
    pub fn right_order(&self) -> u32 {
        let pools = self.pools.lock().unwrap();
        let tables = self.tables.lock().unwrap();
        *pools + *tables
    }

    pub fn sequential(&self) -> u32 {
        let tables = self.tables.lock().unwrap();
        let t = *tables;
        drop(tables);
        let pools = self.pools.lock().unwrap();
        t + *pools
    }
}
