//! Clean panic-free fixture: degrade instead of panicking, and one
//! deliberate panic behind a reasoned fn-level hatch.

pub fn drain(values: &[u32]) -> u32 {
    match values.first() {
        Some(v) => *v,
        None => 0,
    }
}

// analyze: allow(panic_free_module, "fixture: startup-only failure is fatal by design")
pub fn must(flag: bool) {
    if !flag {
        panic!("boom");
    }
}
