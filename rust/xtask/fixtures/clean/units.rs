//! Clean unit fixture: the cross-unit comparison goes through a
//! conversion from the configured allowlist.

pub fn page_budget(free_bytes: usize, want_pages: usize, page_size: usize) -> bool {
    want_pages <= pages_for(free_bytes, page_size)
}

pub fn pages_for(n_bytes: usize, page_size: usize) -> usize {
    n_bytes.div_ceil(page_size)
}
