//! Hatch fixture: an `analyze: allow` without a reason string is itself a
//! finding (and does not suppress anything).

pub fn hatched() -> u32 {
    // analyze: allow(unit_mix)
    1
}
