//! Hot-path fixture: the seed blocks on a lock and panics, and reaches a
//! helper that allocates and panics.

use std::sync::Mutex;

pub struct Ring {
    pub slots: Mutex<Vec<u32>>,
}

pub fn hot_seed(r: &Ring, xs: &[u32]) -> u32 {
    let doubled = helper(xs);
    let guard = r.slots.lock().unwrap();
    doubled + guard.len() as u32
}

fn helper(xs: &[u32]) -> u32 {
    let v = vec![0u32; xs.len()];
    let total: u32 = xs.iter().sum();
    total + v.len() as u32
}
