//! Lock-order fixture: acquires the lower-tier `pools` lock while the
//! higher-tier `tables` guard is still live, and declares one mutex that
//! no `[[lock]]` owner pattern claims.

use std::sync::Mutex;

pub struct State {
    pub pools: Mutex<u32>,
    pub tables: Mutex<u32>,
    pub stray: Mutex<u32>,
}

impl State {
    pub fn wrong_order(&self) -> u32 {
        let tables = self.tables.lock().unwrap();
        let pools = self.pools.lock().unwrap();
        *tables + *pools
    }
}
