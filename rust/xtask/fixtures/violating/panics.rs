//! Panic-free-module fixture: this file is listed in
//! `panic_free_modules`, so any panicking construct is a finding.

pub fn drain(values: &[u32]) -> u32 {
    let first = values.first().unwrap();
    *first
}

pub fn must(flag: bool) {
    if !flag {
        panic!("boom");
    }
}
