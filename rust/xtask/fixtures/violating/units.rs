//! Unit-hygiene fixture: compares a page count against a byte count with
//! no conversion call in the expression.

pub fn page_budget(free_bytes: usize, want_pages: usize) -> bool {
    want_pages < free_bytes
}
