//! Parser for the subset of TOML that `analysis.toml` uses: `[section]`
//! headers, `[[lock]]` array-of-tables, `key = value` with string, integer
//! and (possibly multi-line) string-array values, `#` comments.

use std::path::Path;

/// One `[[lock]]` entry: a named tier in the canonical acquisition order.
#[derive(Debug, Default, Clone)]
pub struct Lock {
    pub name: String,
    pub tier: i64,
    /// Receiver identifiers whose `.lock()` maps to this tier.
    pub receivers: Vec<String>,
    /// `"file.rs:substring"` patterns naming the owning declarations.
    pub owners: Vec<String>,
}

#[derive(Debug, Default, Clone)]
pub struct Config {
    /// Hot-path reachability roots (`name` or `Type::name`).
    pub seeds: Vec<String>,
    /// Identifiers that sanction mixed-unit arithmetic.
    pub conversions: Vec<String>,
    /// Files (relative to the source root) where no non-test fn may panic.
    pub panic_free_modules: Vec<String>,
    pub locks: Vec<Lock>,
}

impl Config {
    pub fn load(path: &Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Ok(Self::parse(&text))
    }

    pub fn parse(text: &str) -> Config {
        let mut cfg = Config::default();
        let mut section = String::new();
        // (key, accumulated value) while an array literal spans lines.
        let mut buf: Option<(String, String)> = None;
        for raw in text.lines() {
            if let Some((key, acc)) = buf.take() {
                let more = strip_comment(raw).trim();
                let acc = format!("{acc} {more}");
                if balanced(&acc) {
                    set_kv(&mut cfg, &section, &key, &acc);
                } else {
                    buf = Some((key, acc));
                }
                continue;
            }
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(inner) = line.strip_prefix("[[") {
                section = inner.trim_end_matches(']').to_string();
                if section == "lock" {
                    cfg.locks.push(Lock::default());
                }
                continue;
            }
            if let Some(inner) = line.strip_prefix('[') {
                section = inner.trim_end_matches(']').to_string();
                continue;
            }
            if let Some((k, v)) = line.split_once('=') {
                let (k, v) = (k.trim().to_string(), v.trim().to_string());
                if !balanced(&v) {
                    buf = Some((k, v));
                    continue;
                }
                set_kv(&mut cfg, &section, &k, &v);
            }
        }
        cfg
    }
}

fn strip_comment(line: &str) -> &str {
    line.split('#').next().unwrap_or("")
}

fn balanced(v: &str) -> bool {
    v.matches('[').count() == v.matches(']').count()
}

fn parse_arr(v: &str) -> Vec<String> {
    let v = v.trim();
    let inner = v
        .strip_prefix('[')
        .and_then(|s| s.strip_suffix(']'))
        .unwrap_or(v)
        .trim();
    if inner.is_empty() {
        return Vec::new();
    }
    inner
        .split(',')
        .map(|x| x.trim().trim_matches('"').to_string())
        .filter(|x| !x.is_empty())
        .collect()
}

fn set_kv(cfg: &mut Config, section: &str, k: &str, v: &str) {
    match (section, k) {
        ("hot_path", "seeds") => cfg.seeds = parse_arr(v),
        ("units", "conversions") => cfg.conversions = parse_arr(v),
        ("resilience", "panic_free_modules") => cfg.panic_free_modules = parse_arr(v),
        ("lock", _) => {
            let Some(lk) = cfg.locks.last_mut() else { return };
            match k {
                "name" => lk.name = v.trim_matches('"').to_string(),
                "tier" => lk.tier = v.trim().parse().unwrap_or(0),
                "receivers" => lk.receivers = parse_arr(v),
                "owners" => lk.owners = parse_arr(v),
                _ => {}
            }
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_arrays_and_lock_tables() {
        let cfg = Config::parse(
            r#"
# comment
[hot_path]
seeds = ["a", "Ty::b"]  # trailing comment

[units]
conversions = [
    "page_bytes",
    "pages_for",
]

[resilience]
panic_free_modules = ["coordinator/server.rs"]

[[lock]]
name = "pools"
tier = 20
receivers = ["pools"]
owners = ["coordinator/pools.rs:pub pools"]

[[lock]]
name = "ring"
tier = 60
receivers = []
owners = []
"#,
        );
        assert_eq!(cfg.seeds, ["a", "Ty::b"]);
        assert_eq!(cfg.conversions, ["page_bytes", "pages_for"]);
        assert_eq!(cfg.panic_free_modules, ["coordinator/server.rs"]);
        assert_eq!(cfg.locks.len(), 2);
        assert_eq!(cfg.locks[0].name, "pools");
        assert_eq!(cfg.locks[0].tier, 20);
        assert_eq!(cfg.locks[0].receivers, ["pools"]);
        assert_eq!(cfg.locks[1].tier, 60);
        assert!(cfg.locks[1].receivers.is_empty());
    }
}
