//! A deliberately small Rust lexer: enough token structure for the lints
//! (identifiers, numbers, single-char punctuation, collapsed string/char
//! literals, lifetimes) plus extraction of `// analyze: allow(..)` hatches.
//! Comments and literal *contents* never become tokens, so the lints cannot
//! false-positive on text inside them.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    Str,
    Char,
    Life,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    fn new(kind: Kind, text: impl Into<String>, line: u32) -> Self {
        Self { kind, text: text.into(), line }
    }
    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }
}

/// line → hatches on that line, as `(lint, reason)` pairs.
pub type Allows = BTreeMap<u32, Vec<(String, String)>>;

/// Parse `// analyze: allow(lint, "reason")`; reason may be unquoted and
/// may itself contain parentheses (the trailing `)` closes the allow).
fn parse_allow(comment: &str) -> Option<(String, String)> {
    let rest = comment.strip_prefix("//")?.trim_start();
    let rest = rest.strip_prefix("analyze:")?.trim_start();
    let rest = rest.strip_prefix("allow(")?;
    let close = rest.rfind(')')?;
    if !rest[close + 1..].trim().is_empty() {
        return None;
    }
    let inner = &rest[..close];
    let (lint, reason) = match inner.split_once(',') {
        Some((l, r)) => (l.trim(), r.trim()),
        None => (inner.trim(), ""),
    };
    if lint.is_empty() || !lint.chars().all(|c| c.is_ascii_lowercase() || c == '_') {
        return None;
    }
    Some((lint.to_string(), reason.trim_matches('"').trim().to_string()))
}

fn starts(s: &[char], i: usize, pat: &str) -> bool {
    pat.chars().enumerate().all(|(k, c)| s.get(i + k) == Some(&c))
}

pub fn lex(src: &str) -> (Vec<Tok>, Allows) {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut allows: Allows = BTreeMap::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    while i < n {
        let c = s[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        if starts(&s, i, "//") {
            let j = (i..n).find(|&k| s[k] == '\n').unwrap_or(n);
            let comment: String = s[i..j].iter().collect();
            if let Some((lint, reason)) = parse_allow(&comment) {
                allows.entry(line).or_default().push((lint, reason));
            }
            i = j;
            continue;
        }
        if starts(&s, i, "/*") {
            let mut depth = 1usize;
            let mut i2 = i + 2;
            while i2 < n && depth > 0 {
                if starts(&s, i2, "/*") {
                    depth += 1;
                    i2 += 2;
                } else if starts(&s, i2, "*/") {
                    depth -= 1;
                    i2 += 2;
                } else {
                    if s[i2] == '\n' {
                        line += 1;
                    }
                    i2 += 1;
                }
            }
            i = i2;
            continue;
        }
        let maybe_str = c == '"'
            || (c == 'r' && i + 1 < n && (s[i + 1] == '"' || s[i + 1] == '#'))
            || starts(&s, i, "b\"")
            || (starts(&s, i, "br") && i + 2 < n && (s[i + 2] == '"' || s[i + 2] == '#'));
        if maybe_str {
            let mut j = i;
            if s[j] == 'b' {
                j += 1;
            }
            let mut handled = false;
            if j < n && s[j] == 'r' {
                j += 1;
                let mut hashes = 0usize;
                while j < n && s[j] == '#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && s[j] == '"' {
                    // Raw string: scan for `"###...` closer.
                    j += 1;
                    let endpat: String =
                        std::iter::once('"').chain(std::iter::repeat('#').take(hashes)).collect();
                    let mut k = j;
                    while k < n && !starts(&s, k, &endpat) {
                        k += 1;
                    }
                    line += s[i..k.min(n)].iter().filter(|&&x| x == '\n').count() as u32;
                    toks.push(Tok::new(Kind::Str, "\"\"", line));
                    i = (k + endpat.chars().count()).min(n);
                    handled = true;
                }
                // Not a raw string (`r#ident` raw identifier, or a lone
                // `r`): fall through to the ident branch below.
            }
            if !handled && s[i] == '"' {
                let mut k = i + 1;
                while k < n {
                    if s[k] == '\\' {
                        k += 2;
                        continue;
                    }
                    if s[k] == '"' {
                        break;
                    }
                    if s[k] == '\n' {
                        line += 1;
                    }
                    k += 1;
                }
                toks.push(Tok::new(Kind::Str, "\"\"", line));
                i = (k + 1).min(n + 1);
                continue;
            }
            if !handled && starts(&s, i, "b\"") {
                let mut k = i + 2;
                while k < n {
                    if s[k] == '\\' {
                        k += 2;
                        continue;
                    }
                    if s[k] == '"' {
                        break;
                    }
                    if s[k] == '\n' {
                        line += 1;
                    }
                    k += 1;
                }
                toks.push(Tok::new(Kind::Str, "\"\"", line));
                i = (k + 1).min(n + 1);
                continue;
            }
            if handled {
                continue;
            }
        }
        if c == '\'' {
            if i + 2 < n && (s[i + 2] == '\'' || s[i + 1] == '\\') {
                // Char literal (covers '\n', 'x', and multi-escape forms).
                let mut j = i + 2;
                while j < n && s[j] != '\'' {
                    j += 1;
                }
                toks.push(Tok::new(Kind::Char, "''", line));
                i = j + 1;
                continue;
            }
            // Lifetime: 'a, 'static, or the label form 'outer.
            let mut j = i + 1;
            while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
                j += 1;
            }
            let text: String = s[i..j].iter().collect();
            toks.push(Tok::new(Kind::Life, text, line));
            i = j;
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && (s[j].is_alphanumeric() || s[j] == '_') {
                j += 1;
            }
            let text: String = s[i..j].iter().collect();
            toks.push(Tok::new(Kind::Ident, text, line));
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && (s[j].is_alphanumeric() || s[j] == '.' || s[j] == '_') {
                j += 1;
            }
            let text: String = s[i..j].iter().collect();
            toks.push(Tok::new(Kind::Num, text, line));
            i = j;
            continue;
        }
        toks.push(Tok::new(Kind::Punct, c.to_string(), line));
        i += 1;
    }
    (toks, allows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).0.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn strings_and_comments_produce_no_inner_tokens() {
        let toks = texts(r##"let x = "a.unwrap()"; // panic!() in comment"##);
        assert_eq!(toks, ["let", "x", "=", "\"\"", ";"]);
        let toks = texts("let y = r#\"vec![0]\"#; /* .lock() */ y");
        assert_eq!(toks, ["let", "y", "=", "\"\"", ";", "y"]);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = lex("fn f<'a>(x: &'a u8) { let c = 'x'; let nl = '\\n'; }").0;
        let lifes: Vec<&str> =
            toks.iter().filter(|t| t.kind == Kind::Life).map(|t| t.text.as_str()).collect();
        assert_eq!(lifes, ["'a", "'a"]);
        assert_eq!(toks.iter().filter(|t| t.kind == Kind::Char).count(), 2);
    }

    #[test]
    fn allow_hatches_are_captured_with_line_numbers() {
        let (_, allows) = lex(
            "fn f() {}\n// analyze: allow(hot_path_alloc, \"why (with parens)\")\nfn g() {}\n// analyze: allow(lock_order)\n",
        );
        assert_eq!(allows[&2], [("hot_path_alloc".into(), "why (with parens)".into())]);
        assert_eq!(allows[&4], [("lock_order".into(), String::new())]);
    }

    #[test]
    fn multiline_string_advances_line_numbers() {
        let toks = lex("let a = \"x\ny\";\nlet b = 1;").0;
        let b = toks.iter().find(|t| t.is("b")).unwrap();
        assert_eq!(b.line, 3);
    }
}
