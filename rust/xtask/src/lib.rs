//! `cargo xtask analyze` — repo-local static analysis for `rust/src`.
//!
//! Three lint families, configured by the checked-in `analysis.toml`:
//!
//! - **lock-hierarchy**: locks must be acquired in ascending tier order
//!   (`lock_order`), every owning `Mutex` must be registered with a tier
//!   (`unregistered_mutex`), and no blocking `.lock()` may appear in code
//!   reachable from the decode hot path (`hot_path_blocking_lock`);
//! - **hot-path hygiene**: no panicking constructs (`hot_path_panic`) and
//!   no heap allocation (`hot_path_alloc`) in functions reachable from the
//!   configured seeds;
//! - **unit hygiene**: no arithmetic mixing `_bytes`/`_pages`/`_tokens`
//!   identifiers without a conversion call (`unit_mix`);
//!
//! plus `panic_free_module` (configured files must not panic anywhere) and
//! `allow_missing_reason` (every escape hatch must say why).
//!
//! Findings can be suppressed with `// analyze: allow(<lint>, "reason")`
//! on (or directly above) the offending line; placed directly above a `fn`,
//! the hatch covers the whole fn and — for the hot-path lints — its entire
//! call subtree. The reason string is mandatory and every hatch is
//! enumerated in the report, so suppressions stay auditable.

#![forbid(unsafe_code)]

pub mod config;
pub mod lexer;
pub mod lints;
pub mod model;

pub use config::Config;
pub use lints::{analyze, Finding, Report};
pub use model::Tree;

use std::path::Path;

/// Load `src_root` and run every lint under `cfg`.
pub fn run(src_root: &Path, cfg: &Config) -> Result<Report, String> {
    let tree = Tree::load(src_root)?;
    Ok(analyze(&tree, cfg))
}
