//! The lint passes. Each produces [`Finding`]s; `analyze` runs them all
//! and returns a [`Report`] with findings sorted by `(file, line, lint)`
//! plus every `// analyze: allow` hatch found in the tree.

use crate::config::Config;
use crate::lexer::Kind;
use crate::model::Tree;
use std::collections::{HashMap, HashSet};

pub const PANIC_METHODS: &[&str] = &["unwrap", "expect"];
pub const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];
pub const ALLOC_PATHS: &[(&str, &str)] = &[
    ("Vec", "new"),
    ("Vec", "with_capacity"),
    ("String", "new"),
    ("String", "from"),
    ("Box", "new"),
    ("VecDeque", "new"),
    ("VecDeque", "with_capacity"),
    ("HashMap", "new"),
    ("BTreeMap", "new"),
];
pub const ALLOC_MACROS: &[&str] = &["vec", "format"];
pub const ALLOC_METHODS: &[&str] =
    &["to_vec", "to_string", "to_owned", "push", "push_back", "push_front", "collect", "clone"];
/// Chain links a `.lock()` guard may pass through and still be the bound
/// value of its `let` statement.
const UNWRAPS: &[&str] = &["unwrap", "expect", "unwrap_or_else"];
const UNIT_SUFFIXES: &[(&str, &str)] =
    &[("_bytes", "bytes"), ("_pages", "pages"), ("_tokens", "tokens")];
const UNIT_OPS: &[&str] = &["+", "-", "<", ">"];

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub lint: String,
    pub file: String,
    pub line: u32,
    /// Enclosing fn qual, or `-` for file-level checks.
    pub ctx: String,
    pub what: String,
}

#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    /// Every hatch in the tree: `(file, line, lint, reason)`.
    pub allows: Vec<(String, u32, String, String)>,
}

impl Report {
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: [{}] {}: {}\n", f.file, f.line, f.lint, f.ctx, f.what));
        }
        out.push_str(&format!("\n{} finding(s).\n", self.findings.len()));
        out.push_str(&format!("\nallow hatches in tree ({}):\n", self.allows.len()));
        for (file, line, lint, reason) in &self.allows {
            let reason = if reason.is_empty() { "<MISSING REASON>" } else { reason };
            out.push_str(&format!("  {file}:{line}: allow({lint}) — {reason}\n"));
        }
        out
    }
}

pub fn analyze(tree: &Tree, cfg: &Config) -> Report {
    let mut findings = Vec::new();
    scan_hot(tree, cfg, "hot_path_panic", &mut findings);
    scan_hot(tree, cfg, "hot_path_alloc", &mut findings);
    scan_hot(tree, cfg, "hot_path_blocking_lock", &mut findings);
    scan_lock_order(tree, cfg, &mut findings);
    scan_units(tree, cfg, &mut findings);
    scan_panic_free(tree, cfg, &mut findings);
    scan_unregistered_mutexes(tree, cfg, &mut findings);

    let mut allows = Vec::new();
    for (file, al) in &tree.allows {
        for (&line, entries) in al {
            for (lint, reason) in entries {
                allows.push((file.clone(), line, lint.clone(), reason.clone()));
                if reason.is_empty() {
                    findings.push(Finding {
                        lint: "allow_missing_reason".into(),
                        file: file.clone(),
                        line,
                        ctx: "-".into(),
                        what: format!("allow({lint}) without a reason string"),
                    });
                }
            }
        }
    }
    findings.sort_by(|a, b| {
        (&a.file, a.line, &a.lint, &a.ctx, &a.what).cmp(&(&b.file, b.line, &b.lint, &b.ctx, &b.what))
    });
    Report { findings, allows }
}

/// Hot-path hygiene: walk the call graph from the seeds and flag panicking
/// constructs, heap allocation, or blocking `.lock()` in reachable fns.
fn scan_hot(tree: &Tree, cfg: &Config, lint: &str, findings: &mut Vec<Finding>) {
    for idx in tree.reach_from_seeds(&cfg.seeds, lint) {
        let fi = &tree.fns[idx];
        let body = &fi.body;
        let n = body.len();
        for i in 0..n {
            let t = &body[i];
            if t.kind != Kind::Ident {
                continue;
            }
            let nxt = if i + 1 < n { body[i + 1].text.as_str() } else { "" };
            let prv = if i > 0 { body[i - 1].text.as_str() } else { "" };
            let prv2 = if i > 1 { body[i - 2].text.as_str() } else { "" };
            let name = t.text.as_str();
            let what: Option<String> = match lint {
                "hot_path_panic" => {
                    if PANIC_METHODS.contains(&name) && prv == "." && nxt == "(" {
                        Some(format!(".{name}()"))
                    } else if PANIC_MACROS.contains(&name) && nxt == "!" {
                        Some(format!("{name}!"))
                    } else {
                        None
                    }
                }
                "hot_path_alloc" => {
                    if ALLOC_MACROS.contains(&name) && nxt == "!" {
                        Some(format!("{name}!"))
                    } else if ALLOC_METHODS.contains(&name) && prv == "." && nxt == "(" {
                        Some(format!(".{name}()"))
                    } else if nxt == "(" && prv == ":" && prv2 == ":" {
                        let ty = if i > 2 { body[i - 3].text.as_str() } else { "" };
                        if ALLOC_PATHS.contains(&(ty, name)) {
                            Some(format!("{ty}::{name}"))
                        } else {
                            None
                        }
                    } else {
                        None
                    }
                }
                "hot_path_blocking_lock" => {
                    if name == "lock" && prv == "." && nxt == "(" {
                        Some(".lock()".to_string())
                    } else {
                        None
                    }
                }
                _ => None,
            };
            if let Some(what) = what {
                if !tree.line_allowed(&fi.file, t.line, lint) {
                    findings.push(Finding {
                        lint: lint.into(),
                        file: fi.file.clone(),
                        line: t.line,
                        ctx: fi.qual.clone(),
                        what,
                    });
                }
            }
        }
    }
}

/// A live lock guard inside a fn body.
struct Guard {
    bind: String,
    recv: String,
    tier: i64,
    lock_name: String,
    depth: i64,
    line: u32,
}

/// Lock-hierarchy lint: within each fn, track `let`-bound guards from
/// tiered receivers and flag any `.lock()`/`.try_lock()` on a receiver of
/// equal-or-lower tier while a guard is live. Guards die at the end of
/// their block or at an explicit `drop(name)`.
fn scan_lock_order(tree: &Tree, cfg: &Config, findings: &mut Vec<Finding>) {
    let mut recv_tier: HashMap<&str, (i64, &str)> = HashMap::new();
    for lk in &cfg.locks {
        for r in &lk.receivers {
            recv_tier.insert(r.as_str(), (lk.tier, lk.name.as_str()));
        }
    }
    for fi in &tree.fns {
        if fi.is_test {
            continue;
        }
        let body = &fi.body;
        let n = body.len();
        let mut guards: Vec<Guard> = Vec::new();
        let mut depth: i64 = 0;
        let mut i = 0usize;
        while i < n {
            let t = &body[i];
            if t.is("{") {
                depth += 1;
            } else if t.is("}") {
                depth -= 1;
                guards.retain(|g| g.depth <= depth);
            } else if t.kind == Kind::Ident
                && t.is("drop")
                && i + 1 < n
                && body[i + 1].is("(")
                && i + 3 < n
                && body[i + 2].kind == Kind::Ident
                && body[i + 3].is(")")
            {
                let victim = body[i + 2].text.clone();
                guards.retain(|g| g.bind != victim);
            } else if t.kind == Kind::Ident
                && (t.is("lock") || t.is("try_lock"))
                && i > 0
                && body[i - 1].is(".")
                && i + 1 < n
                && body[i + 1].is("(")
            {
                let recv =
                    if i > 1 { body[i - 2].text.clone() } else { "?".to_string() };
                let tier = recv_tier.get(recv.as_str()).copied();
                if let Some((new_tier, lock_name)) = tier {
                    for g in &guards {
                        if new_tier <= g.tier
                            && !tree.line_allowed(&fi.file, t.line, "lock_order")
                            && !tree.fn_allowed(fi, "lock_order")
                        {
                            findings.push(Finding {
                                lint: "lock_order".into(),
                                file: fi.file.clone(),
                                line: t.line,
                                ctx: fi.qual.clone(),
                                what: format!(
                                    "{recv}.{}() [{lock_name}/{new_tier}] while holding {} [{}/{}] since line {}",
                                    t.text, g.recv, g.lock_name, g.tier, g.line
                                ),
                            });
                            break;
                        }
                    }
                }
                // Is this a let-bound guard that lives past the statement?
                // Walk over the call parens, then any unwrap/expect/
                // unwrap_or_else links; a `;` right after means the chain's
                // value — the guard — is what got bound.
                let mut j = i + 2;
                let mut pd = 1i64;
                while j < n && pd > 0 {
                    if body[j].is("(") {
                        pd += 1;
                    } else if body[j].is(")") {
                        pd -= 1;
                    }
                    j += 1;
                }
                loop {
                    if j < n
                        && body[j].is(".")
                        && j + 1 < n
                        && body[j + 1].kind == Kind::Ident
                        && UNWRAPS.contains(&body[j + 1].text.as_str())
                    {
                        j += 2;
                        if j < n && body[j].is("(") {
                            let mut pd = 1i64;
                            j += 1;
                            while j < n && pd > 0 {
                                if body[j].is("(") {
                                    pd += 1;
                                } else if body[j].is(")") {
                                    pd -= 1;
                                }
                                j += 1;
                            }
                        }
                        continue;
                    }
                    break;
                }
                if j < n && body[j].is(";") {
                    let mut b = i;
                    while b > 0 && !body[b].is(";") && !body[b].is("{") && !body[b].is("}") {
                        b -= 1;
                    }
                    let has_let = (b..i).any(|x| body[x].is("let"));
                    if has_let {
                        if let Some((new_tier, lock_name)) = tier {
                            // Binding name: first ident after `let` that
                            // isn't `mut`.
                            let mut bind = None;
                            for x in b..i {
                                if body[x].is("let") {
                                    for y in x + 1..i {
                                        if body[y].kind == Kind::Ident && !body[y].is("mut") {
                                            bind = Some(body[y].text.clone());
                                            break;
                                        }
                                    }
                                    break;
                                }
                            }
                            guards.push(Guard {
                                bind: bind.unwrap_or_else(|| "?".to_string()),
                                recv,
                                tier: new_tier,
                                lock_name: lock_name.to_string(),
                                depth,
                                line: t.line,
                            });
                        }
                    }
                }
            }
            i += 1;
        }
    }
}

fn unit_of(name: &str) -> Option<&'static str> {
    UNIT_SUFFIXES.iter().find(|(suf, _)| name.ends_with(suf)).map(|&(_, u)| u)
}

/// Unit hygiene: within each expression fragment (split at `;,{}`, `,` and
/// `&&`/`||`), arithmetic or comparison over identifiers carrying two or
/// more distinct unit suffixes, with no conversion call in sight, is a
/// likely unit error.
fn scan_units(tree: &Tree, cfg: &Config, findings: &mut Vec<Finding>) {
    let conv: HashSet<&str> = cfg.conversions.iter().map(|s| s.as_str()).collect();
    for fi in &tree.fns {
        if fi.is_test {
            continue;
        }
        let body = &fi.body;
        let n = body.len();
        let mut frag: Vec<usize> = Vec::new();
        let check = |frag: &[usize], findings: &mut Vec<Finding>| {
            if frag.is_empty() {
                return;
            }
            let mut units: HashSet<&str> = HashSet::new();
            let mut has_conv = false;
            let mut has_op = false;
            let line = body[frag[0]].line;
            for &x in frag {
                let t = &body[x];
                if t.kind == Kind::Ident {
                    if let Some(u) = unit_of(&t.text) {
                        units.insert(u);
                    }
                    if conv.contains(t.text.as_str()) {
                        has_conv = true;
                    }
                } else if t.kind == Kind::Punct && UNIT_OPS.contains(&t.text.as_str()) {
                    has_op = true;
                }
            }
            if units.len() >= 2
                && has_op
                && !has_conv
                && !tree.line_allowed(&fi.file, line, "unit_mix")
                && !tree.fn_allowed(fi, "unit_mix")
            {
                let mut us: Vec<&str> = units.into_iter().collect();
                us.sort();
                let txt: Vec<&str> =
                    frag.iter().take(20).map(|&x| body[x].text.as_str()).collect();
                findings.push(Finding {
                    lint: "unit_mix".into(),
                    file: fi.file.clone(),
                    line,
                    ctx: fi.qual.clone(),
                    what: format!("mixes {:?}: {}", us, txt.join(" ")),
                });
            }
        };
        let mut i = 0usize;
        while i < n {
            let t = &body[i];
            let mut boundary =
                t.is(";") || t.is("{") || t.is("}") || t.is(",");
            if !boundary
                && (t.is("&") || t.is("|"))
                && i + 1 < n
                && body[i + 1].text == t.text
            {
                boundary = true;
                i += 1; // skip the pair
            }
            if boundary {
                check(&frag, findings);
                frag.clear();
            } else {
                frag.push(i);
            }
            i += 1;
        }
        check(&frag, findings);
    }
}

/// Panic-free modules: in the configured files, no non-test fn may contain
/// a panicking construct at all (reachability doesn't matter — these are
/// the worker-loop files where a panic kills the serving thread).
fn scan_panic_free(tree: &Tree, cfg: &Config, findings: &mut Vec<Finding>) {
    for fi in &tree.fns {
        if fi.is_test || !cfg.panic_free_modules.contains(&fi.file) {
            continue;
        }
        if tree.fn_allowed(fi, "panic_free_module") {
            continue;
        }
        let body = &fi.body;
        let n = body.len();
        for i in 0..n {
            let t = &body[i];
            if t.kind != Kind::Ident {
                continue;
            }
            let nxt = if i + 1 < n { body[i + 1].text.as_str() } else { "" };
            let prv = if i > 0 { body[i - 1].text.as_str() } else { "" };
            let name = t.text.as_str();
            let what = if PANIC_METHODS.contains(&name) && prv == "." && nxt == "(" {
                Some(format!(".{name}()"))
            } else if PANIC_MACROS.contains(&name) && nxt == "!" {
                Some(format!("{name}!"))
            } else {
                None
            };
            if let Some(what) = what {
                if !tree.line_allowed(&fi.file, t.line, "panic_free_module") {
                    findings.push(Finding {
                        lint: "panic_free_module".into(),
                        file: fi.file.clone(),
                        line: t.line,
                        ctx: fi.qual.clone(),
                        what,
                    });
                }
            }
        }
    }
}

/// Every owning `Mutex<..>` declaration must be claimed by some `[[lock]]`
/// owner pattern — otherwise it has no tier and the hierarchy is unsound.
/// Borrowed `&Mutex<..>` mentions reference a mutex owned elsewhere.
fn scan_unregistered_mutexes(tree: &Tree, cfg: &Config, findings: &mut Vec<Finding>) {
    let mut owner_pats: HashMap<&str, Vec<&str>> = HashMap::new();
    for lk in &cfg.locks {
        for o in &lk.owners {
            let (file, pat) = o.split_once(':').unwrap_or((o.as_str(), ""));
            owner_pats.entry(file).or_default().push(pat);
        }
    }
    for (rel, toks) in &tree.files {
        let n = toks.len();
        for i in 0..n {
            let t = &toks[i];
            if t.kind != Kind::Ident || !t.is("Mutex") {
                continue;
            }
            if !(i + 1 < n && toks[i + 1].is("<")) {
                continue;
            }
            let borrowed =
                (i.saturating_sub(2)..i).any(|j| toks[j].is("&"));
            if borrowed {
                continue;
            }
            let lines = &tree.lines[rel];
            let text = lines.get(t.line as usize - 1).map(|s| s.as_str()).unwrap_or("");
            let covered = owner_pats
                .get(rel.as_str())
                .is_some_and(|pats| pats.iter().any(|p| text.contains(p)));
            if covered {
                continue;
            }
            findings.push(Finding {
                lint: "unregistered_mutex".into(),
                file: rel.clone(),
                line: t.line,
                ctx: "-".into(),
                what: "Mutex declaration not covered by any [[lock]] owner in analysis.toml"
                    .into(),
            });
        }
    }
}
