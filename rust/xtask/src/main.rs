#![forbid(unsafe_code)]

use std::path::PathBuf;
use std::process::ExitCode;

fn usage() -> ! {
    eprintln!(
        "usage: cargo xtask analyze [--root <src_dir>] [--config <analysis.toml>]\n\
         \n\
         Runs the repo's static analysis (lock hierarchy, hot-path hygiene,\n\
         unit hygiene) and exits non-zero if any finding is reported."
    );
    std::process::exit(2);
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(|s| s.as_str()) != Some("analyze") {
        usage();
    }
    // Defaults are relative to this crate so the tool works from any cwd.
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut root = manifest.join("../src");
    let mut config = manifest.join("../../analysis.toml");
    let mut it = args[1..].iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(v) => root = PathBuf::from(v),
                None => usage(),
            },
            "--config" => match it.next() {
                Some(v) => config = PathBuf::from(v),
                None => usage(),
            },
            _ => usage(),
        }
    }
    let cfg = match xtask::Config::load(&config) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("xtask: {e}");
            return ExitCode::from(2);
        }
    };
    let report = match xtask::run(&root, &cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("xtask: {e}");
            return ExitCode::from(2);
        }
    };
    print!("{}", report.render());
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
