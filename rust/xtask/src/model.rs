//! Function extraction and the name-resolution call graph.
//!
//! Extraction is brace-depth based: it tracks `impl` blocks (for `self.`
//! receiver resolution), skips `#[cfg(test)] mod` subtrees and `#[test]`
//! functions, and records each fn's body as a token slice. Resolution is
//! deliberately conservative-by-name: a plain `name(..)` or `.name(..)`
//! call resolves to *every* non-test fn with that name, except for a
//! no-resolve list of ubiquitous std names; `self.name(..)` resolves only
//! within the enclosing impl type; `Ty::name(..)` resolves only to that
//! qualified name.

use crate::lexer::{lex, Allows, Kind, Tok};
use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::Path;

pub const KEYWORDS: &[&str] = &[
    "if", "else", "while", "for", "loop", "match", "return", "break", "continue", "let", "mut",
    "fn", "pub", "impl", "trait", "struct", "enum", "mod", "use", "crate", "self", "Self",
    "super", "in", "as", "ref", "move", "where", "const", "static", "type", "dyn", "unsafe",
    "extern", "true", "false",
];

/// Calls that never resolve through the by-name table: std/core methods so
/// common that global by-name fanout would connect unrelated code, plus the
/// conventional closure-parameter names (`f`, `g`, `op`, ...) whose calls
/// are indirect anyway, plus `drop` (modeled by the lock lint itself).
pub const NO_RESOLVE: &[&str] = &[
    "new", "default", "push", "insert", "get", "get_mut", "len", "iter", "iter_mut", "clone",
    "lock", "try_lock", "unwrap", "expect", "clear", "resize", "extend", "extend_from_slice",
    "remove", "contains", "contains_key", "map", "and_then", "unwrap_or", "unwrap_or_else",
    "collect", "into_iter", "next", "last", "first", "split_at", "to_vec", "to_string", "min",
    "max", "abs", "sum", "count", "take", "skip", "chunks", "windows", "zip", "enumerate", "rev",
    "filter", "fold", "any", "all", "find", "position", "sort", "sort_by", "sort_by_key",
    "drain", "append", "retain", "entry", "keys", "values", "values_mut", "is_empty", "as_ref",
    "as_mut", "as_str", "as_slice", "fill", "copy_from_slice", "from", "into", "send", "recv",
    "write", "read", "flush", "join", "spawn", "name", "pop", "pop_front", "push_back",
    "push_front", "front", "back", "swap", "sample", "apply", "get_or_init", "cmp", "eq", "ne",
    "fmt", "hash", "borrow", "borrow_mut", "to_owned", "saturating_sub", "saturating_add",
    "wrapping_add", "checked_sub", "checked_add", "min_by_key", "max_by_key", "floor", "ceil",
    "sqrt", "exp", "ln", "powi", "powf", "sin", "cos", "sin_cos", "trailing_zeros", "div_ceil",
    "load", "store", "fetch_add", "fetch_sub", "ok", "err", "is_some", "is_none", "is_ok",
    "is_err", "starts_with", "ends_with", "trim", "split", "parse", "truncate", "elapsed",
    "duration_since", "as_secs_f64", "as_micros", "get_key_value", "cloned", "copied",
    "unwrap_or_default", "id", "path", "exists", "flat_map", "rem_euclid", "to_le_bytes",
    "from_le_bytes", "try_into", "leading_zeros", "rotate_left", "rotate_right", "f", "g", "h",
    "op", "cb", "drop",
];

#[derive(Debug, Clone)]
pub struct FnInfo {
    pub name: String,
    /// Enclosing impl type, if any.
    pub ty: Option<String>,
    /// `Type::name` or bare `name`.
    pub qual: String,
    /// Path relative to the analyzed root, `/`-separated.
    pub file: String,
    pub start_line: u32,
    pub end_line: u32,
    /// Body tokens, outer braces excluded.
    pub body: Vec<Tok>,
    pub is_test: bool,
}

pub fn extract_functions(toks: &[Tok], relpath: &str) -> Vec<FnInfo> {
    let mut fns = Vec::new();
    let n = toks.len();
    let mut i = 0usize;
    // (type name, body depth) for each open impl block.
    let mut impl_stack: Vec<(String, i64)> = Vec::new();
    let mut skip_test_depth: Option<i64> = None;
    let mut depth: i64 = 0;
    let mut pending_attr_test = false;
    while i < n {
        let t = &toks[i];
        if t.kind == Kind::Punct && t.is("{") {
            depth += 1;
            i += 1;
            continue;
        }
        if t.kind == Kind::Punct && t.is("}") {
            depth -= 1;
            if impl_stack.last().is_some_and(|&(_, d)| depth < d) {
                impl_stack.pop();
            }
            if skip_test_depth.is_some_and(|d| depth < d) {
                skip_test_depth = None;
            }
            i += 1;
            continue;
        }
        if t.kind == Kind::Punct && t.is("#") {
            let j = i + 1;
            if j < n && toks[j].is("[") {
                let mut d2 = 1usize;
                let mut j2 = j + 1;
                let mut has_test = false;
                while j2 < n && d2 > 0 {
                    if toks[j2].is("[") {
                        d2 += 1;
                    } else if toks[j2].is("]") {
                        d2 -= 1;
                    } else if toks[j2].is("test") {
                        has_test = true;
                    }
                    j2 += 1;
                }
                if has_test {
                    pending_attr_test = true;
                }
                i = j2;
                continue;
            }
        }
        if t.kind == Kind::Ident && t.is("mod") && pending_attr_test {
            let mut j = i;
            while j < n && !toks[j].is("{") && !toks[j].is(";") {
                j += 1;
            }
            if j < n && toks[j].is("{") {
                if skip_test_depth.is_none() {
                    skip_test_depth = Some(depth + 1);
                }
                depth += 1;
                i = j + 1;
                pending_attr_test = false;
                continue;
            }
            pending_attr_test = false;
            i = j + 1;
            continue;
        }
        if t.kind == Kind::Ident && t.is("impl") {
            let mut j = i + 1;
            let mut idents: Vec<String> = Vec::new();
            let mut gdepth = 0i64;
            while j < n && !(toks[j].is("{") && gdepth == 0) && !toks[j].is(";") {
                let tt = &toks[j];
                if tt.is("<") {
                    gdepth += 1;
                } else if tt.is(">") {
                    gdepth = (gdepth - 1).max(0);
                } else if tt.kind == Kind::Ident && gdepth == 0 {
                    if tt.is("for") {
                        // `impl Trait for Type`: the type is what names
                        // methods, so restart collection after `for`.
                        idents.clear();
                    } else if !tt.is("where") && !tt.is("Send") && !tt.is("Sync") {
                        idents.push(tt.text.clone());
                    }
                }
                j += 1;
            }
            let tyname = idents.last().cloned().unwrap_or_else(|| "?".to_string());
            if j < n && toks[j].is("{") {
                impl_stack.push((tyname, depth + 1));
                depth += 1;
                i = j + 1;
                continue;
            }
            i = j + 1;
            continue;
        }
        if t.kind == Kind::Ident && t.is("fn") {
            let is_test = pending_attr_test || skip_test_depth.is_some();
            pending_attr_test = false;
            let j = i + 1;
            if j < n && toks[j].kind == Kind::Ident {
                let name = toks[j].text.clone();
                let start_line = toks[j].line;
                // Scan the signature (generics/args/return type) for the
                // body `{` or a `;` (trait method declaration).
                let mut pd = 0i64;
                let mut k2 = j + 1;
                while k2 < n {
                    let tt = &toks[k2];
                    if tt.is("(") || tt.is("[") || tt.is("<") {
                        pd += 1;
                    } else if tt.is(")") || tt.is("]") || tt.is(">") {
                        pd = (pd - 1).max(0);
                    } else if tt.is("{") && pd == 0 {
                        break;
                    } else if tt.is(";") && pd == 0 {
                        break;
                    }
                    k2 += 1;
                }
                if k2 < n && toks[k2].is("{") {
                    let mut d2 = 1i64;
                    let mut j2 = k2 + 1;
                    while j2 < n && d2 > 0 {
                        if toks[j2].is("{") {
                            d2 += 1;
                        } else if toks[j2].is("}") {
                            d2 -= 1;
                        }
                        j2 += 1;
                    }
                    let ty = impl_stack.last().map(|(t, _)| t.clone());
                    let qual = match &ty {
                        Some(t) => format!("{t}::{name}"),
                        None => name.clone(),
                    };
                    fns.push(FnInfo {
                        name,
                        ty,
                        qual,
                        file: relpath.to_string(),
                        start_line,
                        end_line: toks[j2 - 1].line,
                        body: toks[k2 + 1..j2 - 1].to_vec(),
                        is_test,
                    });
                    i = j2;
                    continue;
                }
            }
            i += 1;
            continue;
        }
        if t.kind == Kind::Ident
            && ["use", "struct", "enum", "static", "type", "trait"].contains(&t.text.as_str())
        {
            pending_attr_test = false;
        }
        i += 1;
    }
    fns
}

/// The lexed source tree plus fn index tables.
pub struct Tree {
    /// rel path → all tokens (test code included — the unregistered-mutex
    /// scan covers tests too).
    pub files: BTreeMap<String, Vec<Tok>>,
    /// rel path → raw source lines (for owner-pattern matching).
    pub lines: BTreeMap<String, Vec<String>>,
    pub allows: BTreeMap<String, Allows>,
    pub fns: Vec<FnInfo>,
    by_name: HashMap<String, Vec<usize>>,
    by_qual: HashMap<String, Vec<usize>>,
    no_resolve: HashSet<&'static str>,
    keywords: HashSet<&'static str>,
}

impl Tree {
    pub fn load(src_root: &Path) -> Result<Tree, String> {
        let mut rels = Vec::new();
        collect_rs_files(src_root, Path::new(""), &mut rels)?;
        rels.sort();
        let mut files = BTreeMap::new();
        let mut lines = BTreeMap::new();
        let mut allows = BTreeMap::new();
        let mut fns = Vec::new();
        for rel in rels {
            let path = src_root.join(&rel);
            let src = std::fs::read_to_string(&path)
                .map_err(|e| format!("read {}: {e}", path.display()))?;
            let (toks, al) = lex(&src);
            fns.extend(extract_functions(&toks, &rel));
            files.insert(rel.clone(), toks);
            lines.insert(rel.clone(), src.split('\n').map(|s| s.to_string()).collect());
            allows.insert(rel, al);
        }
        let mut by_name: HashMap<String, Vec<usize>> = HashMap::new();
        let mut by_qual: HashMap<String, Vec<usize>> = HashMap::new();
        for (i, f) in fns.iter().enumerate() {
            if f.is_test {
                continue;
            }
            by_name.entry(f.name.clone()).or_default().push(i);
            by_qual.entry(f.qual.clone()).or_default().push(i);
        }
        Ok(Tree {
            files,
            lines,
            allows,
            fns,
            by_name,
            by_qual,
            no_resolve: NO_RESOLVE.iter().copied().collect(),
            keywords: KEYWORDS.iter().copied().collect(),
        })
    }

    /// A finding on `line` is suppressed by a reasoned hatch on the same
    /// line or the line above.
    pub fn line_allowed(&self, file: &str, line: u32, lint: &str) -> bool {
        let Some(al) = self.allows.get(file) else { return false };
        for ln in [line, line.saturating_sub(1)] {
            if let Some(v) = al.get(&ln) {
                if v.iter().any(|(l, r)| l == lint && !r.is_empty()) {
                    return true;
                }
            }
        }
        false
    }

    /// A fn-level hatch sits on the `fn` line or up to two lines above it
    /// (allowing one doc/attribute line between hatch and signature).
    pub fn fn_allowed(&self, fi: &FnInfo, lint: &str) -> bool {
        let Some(al) = self.allows.get(&fi.file) else { return false };
        for ln in [fi.start_line, fi.start_line.saturating_sub(1), fi.start_line.saturating_sub(2)]
        {
            if let Some(v) = al.get(&ln) {
                if v.iter().any(|(l, r)| l == lint && !r.is_empty()) {
                    return true;
                }
            }
        }
        false
    }

    /// Indices of all resolved callees of `fns[idx]`.
    pub fn callees(&self, idx: usize) -> Vec<usize> {
        let fi = &self.fns[idx];
        let body = &fi.body;
        let n = body.len();
        let mut out = Vec::new();
        for i in 0..n {
            let t = &body[i];
            if t.kind != Kind::Ident || self.keywords.contains(t.text.as_str()) {
                continue;
            }
            if !(i + 1 < n && body[i + 1].is("(")) {
                continue;
            }
            let prv = if i > 0 { body[i - 1].text.as_str() } else { "" };
            let prv2 = if i > 1 { body[i - 2].text.as_str() } else { "" };
            if prv == ":" && prv2 == ":" {
                let ty = if i > 2 { body[i - 3].text.as_str() } else { "" };
                if let Some(v) = self.by_qual.get(&format!("{ty}::{}", t.text)) {
                    out.extend(v.iter().copied());
                }
                continue;
            }
            if prv == "." && prv2 == "self" {
                // Resolve only within the enclosing impl type; an
                // unresolvable self-call is skipped rather than fanned out.
                if let Some(ty) = &fi.ty {
                    if let Some(v) = self.by_qual.get(&format!("{ty}::{}", t.text)) {
                        out.extend(v.iter().copied());
                    }
                }
                continue;
            }
            if self.no_resolve.contains(t.text.as_str()) {
                continue;
            }
            if let Some(v) = self.by_name.get(&t.text) {
                out.extend(v.iter().copied());
            }
        }
        out
    }

    /// BFS from the configured seeds. A fn carrying a fn-level hatch for
    /// `barrier_lint` is neither scanned nor descended into: the hatch
    /// asserts its whole subtree is off the hot path for that lint.
    pub fn reach_from_seeds(&self, seeds: &[String], barrier_lint: &str) -> Vec<usize> {
        let mut seen: HashSet<usize> = HashSet::new();
        let mut stack: Vec<usize> = Vec::new();
        for s in seeds {
            let cands = if s.contains("::") { self.by_qual.get(s) } else { self.by_name.get(s) };
            for &i in cands.into_iter().flatten() {
                if self.fn_allowed(&self.fns[i], barrier_lint) {
                    continue;
                }
                if seen.insert(i) {
                    stack.push(i);
                }
            }
        }
        while let Some(i) = stack.pop() {
            for g in self.callees(i) {
                if self.fn_allowed(&self.fns[g], barrier_lint) {
                    continue;
                }
                if seen.insert(g) {
                    stack.push(g);
                }
            }
        }
        let mut v: Vec<usize> = seen.into_iter().collect();
        v.sort_by(|&a, &b| {
            let (fa, fb) = (&self.fns[a], &self.fns[b]);
            (&fa.file, &fa.qual, fa.start_line).cmp(&(&fb.file, &fb.qual, fb.start_line))
        });
        v
    }
}

fn collect_rs_files(root: &Path, rel: &Path, out: &mut Vec<String>) -> Result<(), String> {
    let dir = root.join(rel);
    let entries =
        std::fs::read_dir(&dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    let mut names: Vec<_> = entries
        .filter_map(|e| e.ok())
        .map(|e| (e.file_name(), e.path()))
        .collect();
    names.sort();
    for (name, path) in names {
        let sub = rel.join(&name);
        if path.is_dir() {
            collect_rs_files(root, &sub, out)?;
        } else if name.to_string_lossy().ends_with(".rs") {
            // `/`-separated keys so findings and config patterns agree
            // across platforms.
            let key = sub
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(key);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn fns_of(src: &str) -> Vec<FnInfo> {
        extract_functions(&lex(src).0, "t.rs")
    }

    #[test]
    fn extracts_impl_methods_with_qual_names() {
        let fns = fns_of("struct A; impl A { fn m(&self) { self.n(); } fn n(&self) {} } fn free() {}");
        let quals: Vec<&str> = fns.iter().map(|f| f.qual.as_str()).collect();
        assert_eq!(quals, ["A::m", "A::n", "free"]);
        assert_eq!(fns[0].ty.as_deref(), Some("A"));
        assert_eq!(fns[2].ty, None);
    }

    #[test]
    fn trait_impls_resolve_to_the_type() {
        let fns = fns_of("impl Display for Thing { fn fmt(&self) {} }");
        assert_eq!(fns[0].qual, "Thing::fmt");
    }

    #[test]
    fn test_code_is_marked() {
        let fns = fns_of(
            "fn live() {}\n#[cfg(test)]\nmod tests { fn helper() {} #[test] fn t() {} }\n#[test]\nfn top_t() {}",
        );
        let tests: Vec<(&str, bool)> =
            fns.iter().map(|f| (f.name.as_str(), f.is_test)).collect();
        assert_eq!(
            tests,
            [("live", false), ("helper", true), ("t", true), ("top_t", true)]
        );
    }

    #[test]
    fn generic_signatures_find_their_body() {
        let fns = fns_of("fn f<T: Into<Vec<u8>>>(x: T) -> Vec<u8> { x.into() }");
        assert_eq!(fns.len(), 1);
        assert!(!fns[0].body.is_empty());
    }
}
