//! Analyzer self-tests: the violating fixture tree must produce exactly
//! the pinned findings (lint, file, line), the clean tree none.

use std::path::PathBuf;
use xtask::{run, Config};

fn fixture(dir: &str) -> (PathBuf, Config) {
    let base = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("fixtures");
    let cfg = Config::load(&base.join("analysis.toml")).expect("fixture config");
    (base.join(dir), cfg)
}

#[test]
fn violating_tree_produces_exactly_the_seeded_findings() {
    let (root, cfg) = fixture("violating");
    let report = run(&root, &cfg).expect("analyze violating fixtures");
    let got: Vec<(String, String, u32)> = report
        .findings
        .iter()
        .map(|f| (f.lint.clone(), f.file.clone(), f.line))
        .collect();
    let want: Vec<(String, String, u32)> = [
        ("allow_missing_reason", "allows.rs", 5),
        ("hot_path_blocking_lock", "hot.rs", 12),
        ("hot_path_panic", "hot.rs", 12),
        ("hot_path_alloc", "hot.rs", 17),
        ("unregistered_mutex", "locks.rs", 10),
        ("lock_order", "locks.rs", 16),
        ("panic_free_module", "panics.rs", 5),
        ("panic_free_module", "panics.rs", 11),
        ("unit_mix", "units.rs", 5),
    ]
    .iter()
    .map(|&(l, f, n)| (l.to_string(), f.to_string(), n))
    .collect();
    assert_eq!(got, want, "full report:\n{}", report.render());
}

#[test]
fn violating_lock_order_names_both_tiers() {
    let (root, cfg) = fixture("violating");
    let report = run(&root, &cfg).unwrap();
    let lo = report.findings.iter().find(|f| f.lint == "lock_order").expect("lock_order finding");
    assert_eq!(lo.ctx, "State::wrong_order");
    assert!(lo.what.contains("[pools/10]"), "{}", lo.what);
    assert!(lo.what.contains("[tables/20]"), "{}", lo.what);
    assert!(lo.what.contains("since line 15"), "{}", lo.what);
}

#[test]
fn clean_tree_produces_no_findings_and_enumerates_hatches() {
    let (root, cfg) = fixture("clean");
    let report = run(&root, &cfg).expect("analyze clean fixtures");
    assert!(report.findings.is_empty(), "unexpected findings:\n{}", report.render());
    let hatches: Vec<(&str, u32, &str)> = report
        .allows
        .iter()
        .map(|(f, n, l, _)| (f.as_str(), *n, l.as_str()))
        .collect();
    assert_eq!(
        hatches,
        [("allows.rs", 5, "unit_mix"), ("panics.rs", 11, "panic_free_module")]
    );
    assert!(report.allows.iter().all(|(_, _, _, reason)| !reason.is_empty()));
}

#[test]
fn every_violating_finding_is_reported_in_file_line_format() {
    let (root, cfg) = fixture("violating");
    let report = run(&root, &cfg).unwrap();
    let rendered = report.render();
    assert!(rendered.contains("locks.rs:16: [lock_order]"), "{rendered}");
    assert!(rendered.contains("units.rs:5: [unit_mix]"), "{rendered}");
    assert!(rendered.contains("9 finding(s)."), "{rendered}");
}
