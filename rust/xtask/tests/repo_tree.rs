//! Checks against the real `rust/src` tree with the real `analysis.toml`:
//! the lock-tier registry must cover every owning `Mutex` declaration, and
//! every escape hatch in the tree must carry a reason.

use std::path::PathBuf;
use xtask::{run, Config};

fn repo_report() -> xtask::Report {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let cfg = Config::load(&manifest.join("../../analysis.toml")).expect("repo analysis.toml");
    run(&manifest.join("../src"), &cfg).expect("analyze rust/src")
}

#[test]
fn analysis_toml_covers_every_mutex_owning_declaration() {
    let report = repo_report();
    let uncovered: Vec<String> = report
        .findings
        .iter()
        .filter(|f| f.lint == "unregistered_mutex")
        .map(|f| format!("{}:{}", f.file, f.line))
        .collect();
    assert!(
        uncovered.is_empty(),
        "Mutex declarations without a [[lock]] tier in analysis.toml: {uncovered:?}"
    );
}

#[test]
fn every_allow_hatch_in_the_tree_carries_a_reason() {
    let report = repo_report();
    let missing: Vec<String> = report
        .allows
        .iter()
        .filter(|(_, _, _, reason)| reason.is_empty())
        .map(|(file, line, lint, _)| format!("{file}:{line} allow({lint})"))
        .collect();
    assert!(missing.is_empty(), "hatches without reasons: {missing:?}");
    // The tree is expected to carry hatches — if this drops to zero the
    // enumeration itself may have broken.
    assert!(!report.allows.is_empty(), "expected at least one enumerated hatch in rust/src");
}
